//! The wire codec: a canonical, versioned, dependency-free binary encoding
//! for [`Packet`]s (frame layout reference: `docs/WIRE.md`).
//!
//! In-process backends ([`super::Lockstep`], [`super::Threaded`]) hand
//! `Packet` structs between halves directly; the TCP backend
//! ([`super::Tcp`]) moves the *bytes* this module produces. The encoding is
//! exact: every `f64` travels as its IEEE-754 bit pattern
//! ([`f64::to_bits`]), so NaN payloads, negative zero and subnormals
//! round-trip bit-for-bit and a decoded packet's [`BitCost`]s reconcile
//! with the in-process tally to the last bit — the property
//! `tests/transport_equivalence.rs` pins across all three backends.
//!
//! Everything is little-endian. A frame is a fixed 34-byte header
//! ([`encode_header`]/[`decode_header`]) followed by `body_len` body bytes;
//! a [`FrameKind::Packet`] body is produced by [`encode_packet`] and
//! consumed by [`decode_packet`]. Decoding is strict: truncated input, bad
//! magic/version, unknown tags or kind ids, non-`0x00`/`0x01` flag bytes
//! and trailing bytes are all `anyhow` errors — the decoder never panics
//! and never trusts a length field beyond the bytes actually present
//! (`rust/tests/wire_codec.rs` drives the rejection paths).
//!
//! Message kinds travel as a `u16` index into [`WIRE_KINDS`], the codec's
//! mirror of the [`super::kinds::KINDS`] registry. The table is
//! **append-only** (ids are positional; reordering or deleting entries is a
//! wire-format break and requires a [`VERSION`] bump). The audit's
//! `codec-sync` rule and its compiled cross-check keep the two tables in
//! lockstep, so a kind cannot be registered without a wire id.

use super::{kinds, Msg, Packet, Payload};
use crate::compressors::BitCost;
use crate::linalg::Mat;
use anyhow::{bail, Context, Result};

/// Frame magic: the first four bytes of every frame ("Basis-Learn Wire
/// Format").
pub const MAGIC: [u8; 4] = *b"BLWF";

/// Wire-format version byte. Bump on any incompatible layout change
/// (including reordering [`WIRE_KINDS`]). v2 added the `Join`/`Assign`
/// handshake frames for standalone worker processes (docs/WIRE.md).
pub const VERSION: u8 = 2;

/// Fixed frame-header length in bytes: magic(4) + version(1) + kind(1) +
/// round(8) + exchange(8) + client(8) + body_len(4).
pub const HEADER_LEN: usize = 34;

/// Hard cap on a frame body. The header's `body_len` is attacker-controlled
/// on a non-loopback connection, so the session layer rejects anything
/// larger *before* allocating — a hostile header is a decode error, never a
/// multi-GiB allocation. 256 MiB is ~3 orders of magnitude above the
/// largest legitimate frame (a full d×d Hessian at paper scale).
pub const MAX_BODY_LEN: usize = 1 << 28;

/// Wire ids for message kinds: `id = position in this table`. Mirrors the
/// names in [`super::kinds::KINDS`] (registry order) and is **append-only**
/// — see the module docs. Checked against the registry by the audit's
/// `codec-sync` rule (source text) and `cross_check_runtime` (compiled).
pub const WIRE_KINDS: &[&str] = &[
    "anchor",
    "avg",
    "beta_gamma",
    "coeff_delta",
    "ctl",
    "delta",
    "direction",
    "g",
    "g1",
    "g2",
    "gbar",
    "grad",
    "grad_coeff",
    "grad_report",
    "grad_update",
    "h_g",
    "hess_coeff",
    "hess_delta",
    "hess_g",
    "model",
    "model_delta",
    "model_residual",
    "model_update",
    "proceed",
    "shift_delta",
    "x",
    "x_try",
    "xi",
];

/// What a frame carries (byte value on the wire; `0` is reserved so an
/// all-zero buffer can never parse as a frame).
///
/// Like [`WIRE_KINDS`], the byte assignment is **append-only**: reusing or
/// renumbering a byte is a wire-format break and requires a [`VERSION`]
/// bump. The [`FRAME_KINDS`] table mirrors this enum and the audit's
/// `codec-sync` rule keeps the two in lockstep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker → server greeting; `client` carries the worker index.
    Hello = 1,
    /// A serialized [`Packet`] (either direction).
    Packet = 2,
    /// Orderly shutdown; the receiver stops reading.
    Bye = 3,
    /// A failure report; the body is a UTF-8 message.
    Error = 4,
    /// Remote worker → server: request to join a listening round loop
    /// (extended handshake, v2). Bodyless; the server replies with
    /// [`FrameKind::Assign`].
    Join = 5,
    /// Server → remote worker: the run assignment (v2). `client` carries
    /// the assigned worker index; the body is an encoded [`Assignment`].
    Assign = 6,
}

/// Frame-kind names and their wire bytes, in byte order. Mirrors
/// [`FrameKind`] exactly (checked by a compiled test and the audit's
/// `codec-sync` rule) and is **append-only** like [`WIRE_KINDS`].
pub const FRAME_KINDS: &[(&str, u8)] = &[
    ("hello", 1),
    ("packet", 2),
    ("bye", 3),
    ("error", 4),
    ("join", 5),
    ("assign", 6),
];

impl FrameKind {
    /// Decode a wire byte (`None` for unknown bytes, including reserved 0).
    pub fn from_byte(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::Hello),
            2 => Some(FrameKind::Packet),
            3 => Some(FrameKind::Bye),
            4 => Some(FrameKind::Error),
            5 => Some(FrameKind::Join),
            6 => Some(FrameKind::Assign),
            _ => None,
        }
    }

    /// The [`FRAME_KINDS`] name of this frame kind.
    pub fn name(&self) -> &'static str {
        match self {
            FrameKind::Hello => "hello",
            FrameKind::Packet => "packet",
            FrameKind::Bye => "bye",
            FrameKind::Error => "error",
            FrameKind::Join => "join",
            FrameKind::Assign => "assign",
        }
    }
}

/// The addressing header every frame carries: which exchange of which round
/// this frame belongs to, and which client it is for/from. The TCP backend
/// verifies these against its expectations on receipt (per-exchange
/// sequencing), so a delayed or misrouted frame is an error, not silent
/// state corruption.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    pub round: u64,
    pub exchange: u64,
    pub client: u64,
}

impl FrameHeader {
    /// Header for a [`Packet`] frame addressed to/from `client`.
    pub fn packet(round: usize, exchange: usize, client: usize) -> Self {
        FrameHeader {
            kind: FrameKind::Packet,
            round: round as u64,
            exchange: exchange as u64,
            client: client as u64,
        }
    }

    /// Header for a control frame (no packet body).
    pub fn control(kind: FrameKind, client: usize) -> Self {
        FrameHeader { kind, round: 0, exchange: 0, client: client as u64 }
    }
}

/// Look up a kind's wire id. Unregistered kinds cannot be encoded: the
/// codec's vocabulary is exactly the registry's.
pub fn wire_id(kind: &str) -> Result<u16> {
    match WIRE_KINDS.iter().position(|k| *k == kind) {
        Some(i) => Ok(i as u16),
        None => bail!("message kind {kind:?} has no wire id (WIRE_KINDS is out of sync)"),
    }
}

/// Append the 34-byte frame header for a `body_len`-byte body to `out`.
pub fn encode_header(h: &FrameHeader, body_len: usize, out: &mut Vec<u8>) -> Result<()> {
    if body_len > MAX_BODY_LEN {
        bail!("frame body of {body_len} bytes exceeds MAX_BODY_LEN ({MAX_BODY_LEN})");
    }
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(h.kind as u8);
    out.extend_from_slice(&h.round.to_le_bytes());
    out.extend_from_slice(&h.exchange.to_le_bytes());
    out.extend_from_slice(&h.client.to_le_bytes());
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    Ok(())
}

/// Decode a frame header; returns the header and the body length that
/// follows. Rejects bad magic, unknown versions and unknown frame kinds.
pub fn decode_header(buf: &[u8; HEADER_LEN]) -> Result<(FrameHeader, usize)> {
    if buf[0..4] != MAGIC {
        bail!("bad frame magic {:02x?} (expected {MAGIC:02x?})", &buf[0..4]);
    }
    if buf[4] != VERSION {
        bail!("unsupported wire version {} (this build speaks {VERSION})", buf[4]);
    }
    let Some(kind) = FrameKind::from_byte(buf[5]) else {
        bail!("unknown frame kind byte {:#04x}", buf[5]);
    };
    let u64_at = |i: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[i..i + 8]);
        u64::from_le_bytes(b)
    };
    let mut len = [0u8; 4];
    len.copy_from_slice(&buf[30..34]);
    let header = FrameHeader {
        kind,
        round: u64_at(6),
        exchange: u64_at(14),
        client: u64_at(22),
    };
    Ok((header, u32::from_le_bytes(len) as usize))
}

/// The body of an [`FrameKind::Assign`] frame: everything a standalone
/// worker process needs to rebuild its share of the run locally (the
/// assigned worker index travels in the frame header's `client` field).
///
/// The config and data recipe cross as their canonical string renderings
/// ([`crate::config::RunConfig::to_wire`] /
/// [`crate::data::DataRecipe::render`]); the fingerprint lets the worker
/// verify that its decoded config is *semantically identical* to the
/// server's before any computation starts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// [`crate::config::RunConfig::fingerprint`] of the server's config.
    pub fingerprint: u64,
    /// Total registered workers K (client `i` is pinned to worker `i % K`).
    pub workers: u64,
    /// Total clients n in the federation.
    pub clients: u64,
    /// Wire rendering of the run config.
    pub config: String,
    /// Wire rendering of the data recipe.
    pub recipe: String,
}

/// Encode an [`Assignment`] body: three u64s, then two u32-length-prefixed
/// UTF-8 strings.
pub fn encode_assign(a: &Assignment, out: &mut Vec<u8>) -> Result<()> {
    out.extend_from_slice(&a.fingerprint.to_le_bytes());
    out.extend_from_slice(&a.workers.to_le_bytes());
    out.extend_from_slice(&a.clients.to_le_bytes());
    for (what, s) in [("config", &a.config), ("recipe", &a.recipe)] {
        encode_len(s.len(), what, out)?;
        out.extend_from_slice(s.as_bytes());
    }
    Ok(())
}

/// Decode an [`Assignment`] body. Strict like [`decode_packet`]: lengths
/// are validated against the bytes present before allocation, the strings
/// must be valid UTF-8, and trailing bytes are an error.
pub fn decode_assign(buf: &[u8]) -> Result<Assignment> {
    let mut r = Reader { buf, pos: 0 };
    let fingerprint = r.u64().context("assignment fingerprint")?;
    let workers = r.u64().context("assignment worker count")?;
    let clients = r.u64().context("assignment client count")?;
    let mut string = |what: &str| -> Result<String> {
        let n = r.u32().with_context(|| format!("assignment {what} length"))? as usize;
        let bytes = r.take(n).with_context(|| format!("assignment {what}"))?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| anyhow::anyhow!("assignment {what} is not UTF-8: {e}"))
    };
    let config = string("config")?;
    let recipe = string("recipe")?;
    if r.pos != buf.len() {
        bail!("{} trailing bytes after the assignment", buf.len() - r.pos);
    }
    Ok(Assignment { fingerprint, workers, clients, config, recipe })
}

/// Encode a packet body into a fresh buffer. See [`encode_packet_into`].
pub fn encode_packet(p: &Packet) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    encode_packet_into(p, &mut out)?;
    Ok(out)
}

/// Append the packet-body encoding of `p` to `out` (layout: `docs/WIRE.md`).
/// Fails — writing nothing useful but possibly a partial body — if a
/// message's kind is not in [`WIRE_KINDS`]; callers encode into a scratch
/// buffer they reset on error.
pub fn encode_packet_into(p: &Packet, out: &mut Vec<u8>) -> Result<()> {
    if p.msgs.len() > u32::MAX as usize {
        bail!("packet with {} messages exceeds the u32 count field", p.msgs.len());
    }
    out.extend_from_slice(&(p.msgs.len() as u32).to_le_bytes());
    for msg in &p.msgs {
        let id = wire_id(msg.kind)?;
        out.extend_from_slice(&id.to_le_bytes());
        out.push(payload_tag(&msg.payload));
        out.extend_from_slice(&msg.cost.floats.to_bits().to_le_bytes());
        out.extend_from_slice(&msg.cost.aux_bits.to_bits().to_le_bytes());
        match &msg.payload {
            Payload::Vector(v) | Payload::Scalars(v) => {
                encode_len(v.len(), "vector length", out)?;
                for x in v {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            Payload::Matrix(m) => {
                encode_len(m.rows(), "matrix rows", out)?;
                encode_len(m.cols(), "matrix cols", out)?;
                for x in m.data() {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            Payload::Flags(f) => {
                encode_len(f.len(), "flag count", out)?;
                out.extend(f.iter().map(|&b| b as u8));
            }
        }
    }
    Ok(())
}

/// Decode a packet body. Strict: every length is validated against the
/// bytes actually remaining before any allocation, unknown kind ids /
/// payload tags / flag bytes are errors, and leftover bytes after the last
/// message are an error. Never panics.
pub fn decode_packet(buf: &[u8]) -> Result<Packet> {
    let mut r = Reader { buf, pos: 0 };
    let count = r.u32().context("packet message count")?;
    let mut msgs = Vec::new();
    for i in 0..count {
        let ctx = || format!("message {i} of {count}");
        let id = r.u16().with_context(ctx)?;
        let kind: &'static str = match WIRE_KINDS.get(id as usize) {
            Some(k) => k,
            None => bail!("unknown wire kind id {id} in message {i}"),
        };
        let tag = r.u8().with_context(ctx)?;
        let cost = BitCost {
            floats: f64::from_bits(r.u64().with_context(ctx)?),
            aux_bits: f64::from_bits(r.u64().with_context(ctx)?),
        };
        let payload = match tag {
            TAG_VECTOR => Payload::Vector(r.f64_vec().with_context(ctx)?),
            TAG_MATRIX => {
                let rows = r.u32().with_context(ctx)? as usize;
                let cols = r.u32().with_context(ctx)? as usize;
                let n = rows
                    .checked_mul(cols)
                    .with_context(|| format!("matrix shape {rows}x{cols} overflows"))?;
                let data = r.f64s(n).with_context(ctx)?;
                Payload::Matrix(Mat::from_vec(rows, cols, data))
            }
            TAG_SCALARS => Payload::Scalars(r.f64_vec().with_context(ctx)?),
            TAG_FLAGS => {
                let n = r.u32().with_context(ctx)? as usize;
                let bytes = r.take(n).with_context(ctx)?;
                let mut flags = Vec::with_capacity(n);
                for &b in bytes {
                    match b {
                        0 => flags.push(false),
                        1 => flags.push(true),
                        _ => bail!("invalid flag byte {b:#04x} in message {i}"),
                    }
                }
                Payload::Flags(flags)
            }
            t => bail!("unknown payload tag {t:#04x} in message {i}"),
        };
        msgs.push(Msg { kind, payload, cost });
    }
    if r.pos != buf.len() {
        bail!("{} trailing bytes after the last message", buf.len() - r.pos);
    }
    Ok(Packet { msgs })
}

const TAG_VECTOR: u8 = 0;
const TAG_MATRIX: u8 = 1;
const TAG_SCALARS: u8 = 2;
const TAG_FLAGS: u8 = 3;

fn payload_tag(p: &Payload) -> u8 {
    match p {
        Payload::Vector(_) => TAG_VECTOR,
        Payload::Matrix(_) => TAG_MATRIX,
        Payload::Scalars(_) => TAG_SCALARS,
        Payload::Flags(_) => TAG_FLAGS,
    }
}

fn encode_len(n: usize, what: &str, out: &mut Vec<u8>) -> Result<()> {
    if n > u32::MAX as usize {
        bail!("{what} {n} exceeds the u32 length field");
    }
    out.extend_from_slice(&(n as u32).to_le_bytes());
    Ok(())
}

/// Bounds-checked little-endian cursor over a body buffer.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let remaining = self.buf.len() - self.pos;
        if n > remaining {
            bail!("truncated frame: need {n} bytes, {remaining} remain");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let mut b = [0u8; 2];
        b.copy_from_slice(self.take(2)?);
        Ok(u16::from_le_bytes(b))
    }

    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }

    /// `n` consecutive f64 bit patterns. The length is checked against the
    /// remaining bytes *before* allocating, so a hostile length field
    /// cannot trigger an over-allocation.
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let nbytes = n.checked_mul(8).with_context(|| format!("{n} floats overflow"))?;
        let bytes = self.take(nbytes)?;
        let mut out = Vec::with_capacity(n);
        for chunk in bytes.chunks_exact(8) {
            let mut b = [0u8; 8];
            b.copy_from_slice(chunk);
            out.push(f64::from_bits(u64::from_le_bytes(b)));
        }
        Ok(out)
    }

    /// A u32 length prefix followed by that many f64 bit patterns.
    fn f64_vec(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        self.f64s(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn packets_bit_equal(a: &Packet, b: &Packet) -> bool {
        a.msgs.len() == b.msgs.len()
            && a.msgs.iter().zip(&b.msgs).all(|(x, y)| {
                x.kind == y.kind
                    && x.cost.floats.to_bits() == y.cost.floats.to_bits()
                    && x.cost.aux_bits.to_bits() == y.cost.aux_bits.to_bits()
                    && payloads_bit_equal(&x.payload, &y.payload)
            })
    }

    fn payloads_bit_equal(a: &Payload, b: &Payload) -> bool {
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        match (a, b) {
            (Payload::Vector(x), Payload::Vector(y)) => bits(x) == bits(y),
            (Payload::Scalars(x), Payload::Scalars(y)) => bits(x) == bits(y),
            (Payload::Flags(x), Payload::Flags(y)) => x == y,
            (Payload::Matrix(x), Payload::Matrix(y)) => {
                x.rows() == y.rows() && x.cols() == y.cols() && bits(x.data()) == bits(y.data())
            }
            _ => false,
        }
    }

    #[test]
    fn wire_kinds_mirror_the_registry() {
        let names: Vec<&str> = kinds::KINDS.iter().map(|k| k.name).collect();
        assert_eq!(WIRE_KINDS, &names[..], "WIRE_KINDS out of sync with kinds::KINDS");
    }

    #[test]
    fn round_trip_every_payload_variant() {
        let mut p = Packet::empty();
        p.push_vector("model", vec![1.0, -0.0, f64::MIN_POSITIVE], BitCost::floats(3));
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        p.push_matrix("hess_delta", m, BitCost { floats: 6.0, aux_bits: 96.0 });
        p.push_scalars("beta_gamma", vec![0.5, -2.5], BitCost::floats(2));
        p.push_flags("xi", vec![true, false, true], BitCost::bits(3.0));
        let body = encode_packet(&p).unwrap();
        let q = decode_packet(&body).unwrap();
        assert!(packets_bit_equal(&p, &q));
    }

    #[test]
    fn special_floats_survive_bit_for_bit() {
        let specials = vec![
            f64::NAN,
            f64::from_bits(0x7ff8_dead_beef_0001),
            -0.0,
            0.0,
            5e-324,
            -5e-324,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MAX,
        ];
        let mut p = Packet::empty();
        p.push_vector("grad", specials.clone(), BitCost::zero());
        let q = decode_packet(&encode_packet(&p).unwrap()).unwrap();
        let got = q.vector("grad").unwrap();
        let want: Vec<u64> = specials.iter().map(|x| x.to_bits()).collect();
        let have: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        assert_eq!(want, have);
    }

    #[test]
    fn empty_shapes_round_trip() {
        let mut p = Packet::empty();
        p.push_vector("grad", vec![], BitCost::zero());
        p.push_matrix("hess_delta", Mat::zeros(0, 0), BitCost::zero());
        p.push_flags("ctl", vec![], BitCost::zero());
        let q = decode_packet(&encode_packet(&p).unwrap()).unwrap();
        assert!(packets_bit_equal(&p, &q));
        let empty = decode_packet(&encode_packet(&Packet::empty()).unwrap()).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn truncation_is_an_error_at_every_prefix() {
        let mut p = Packet::empty();
        p.push_vector("model", vec![1.0, 2.0], BitCost::floats(2));
        p.push_flags("xi", vec![true], BitCost::bits(1.0));
        let body = encode_packet(&p).unwrap();
        for cut in 0..body.len() {
            assert!(decode_packet(&body[..cut]).is_err(), "prefix {cut} decoded");
        }
        assert!(decode_packet(&body).is_ok());
    }

    #[test]
    fn hostile_inputs_are_errors_not_panics() {
        // Unknown kind id.
        let mut body = 1u32.to_le_bytes().to_vec();
        body.extend_from_slice(&u16::MAX.to_le_bytes());
        assert!(decode_packet(&body).is_err());
        // Unknown payload tag.
        let mut p = Packet::empty();
        p.push_vector("model", vec![], BitCost::zero());
        let mut body = encode_packet(&p).unwrap();
        body[6] = 9;
        assert!(decode_packet(&body).is_err());
        // Flag byte that is neither 0 nor 1.
        let mut p = Packet::empty();
        p.push_flags("xi", vec![true], BitCost::bits(1.0));
        let mut body = encode_packet(&p).unwrap();
        let last = body.len() - 1;
        body[last] = 2;
        assert!(decode_packet(&body).is_err());
        // Trailing garbage.
        let mut body = encode_packet(&Packet::empty()).unwrap();
        body.push(0);
        assert!(decode_packet(&body).is_err());
        // A length field far beyond the buffer must not allocate or panic.
        let mut body = 1u32.to_le_bytes().to_vec();
        body.extend_from_slice(&0u16.to_le_bytes()); // kind id 0
        body.push(TAG_VECTOR);
        body.extend_from_slice(&[0u8; 16]); // cost
        body.extend_from_slice(&u32::MAX.to_le_bytes()); // hostile length
        assert!(decode_packet(&body).is_err());
    }

    #[test]
    fn header_round_trip_and_rejection() {
        let h = FrameHeader::packet(7, 2, 5);
        let mut buf = Vec::new();
        encode_header(&h, 42, &mut buf).unwrap();
        assert_eq!(buf.len(), HEADER_LEN);
        let mut arr = [0u8; HEADER_LEN];
        arr.copy_from_slice(&buf);
        let (got, len) = decode_header(&arr).unwrap();
        assert_eq!(got, h);
        assert_eq!(len, 42);

        let mut bad = arr;
        bad[0] = b'X';
        assert!(decode_header(&bad).is_err(), "bad magic accepted");
        let mut bad = arr;
        bad[4] = VERSION + 1;
        assert!(decode_header(&bad).is_err(), "future version accepted");
        let mut bad = arr;
        bad[5] = 0;
        assert!(decode_header(&bad).is_err(), "frame kind 0 accepted");
    }

    #[test]
    fn frame_kinds_mirror_the_enum() {
        // The compiled half of the codec-sync guarantee for frame kinds:
        // the table, `from_byte` and `name` agree, byte 0 stays reserved,
        // and bytes/names are unique.
        for &(name, byte) in FRAME_KINDS {
            assert_ne!(byte, 0, "frame byte 0 is reserved");
            let kind = FrameKind::from_byte(byte)
                .unwrap_or_else(|| panic!("FRAME_KINDS byte {byte} not decodable"));
            assert_eq!(kind as u8, byte, "{name}: discriminant mismatch");
            assert_eq!(kind.name(), name, "byte {byte}: name mismatch");
        }
        for b in 0..=u8::MAX {
            if let Some(kind) = FrameKind::from_byte(b) {
                assert!(
                    FRAME_KINDS.iter().any(|&(_, byte)| byte == b),
                    "decodable byte {b} missing from FRAME_KINDS"
                );
                assert_eq!(kind as u8, b);
            }
        }
        let mut names: Vec<&str> = FRAME_KINDS.iter().map(|&(n, _)| n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), FRAME_KINDS.len(), "duplicate frame-kind name");
    }

    #[test]
    fn assignment_round_trip_and_strictness() {
        let a = Assignment {
            fingerprint: 0xdead_beef_0bad_f00d,
            workers: 3,
            clients: 17,
            config: "algorithm=bl1\nrounds=20".into(),
            recipe: "synth n=5 m=25".into(),
        };
        let mut body = Vec::new();
        encode_assign(&a, &mut body).unwrap();
        assert_eq!(decode_assign(&body).unwrap(), a);
        // Every truncation prefix is an error, never a panic.
        for cut in 0..body.len() {
            assert!(decode_assign(&body[..cut]).is_err(), "prefix {cut} decoded");
        }
        // Trailing garbage is an error.
        body.push(0);
        assert!(decode_assign(&body).is_err());
        // Non-UTF-8 config bytes are an error.
        let mut bad = Vec::new();
        encode_assign(&Assignment { config: "ab".into(), ..a.clone() }, &mut bad).unwrap();
        let cfg_at = 8 * 3 + 4;
        bad[cfg_at] = 0xff;
        bad[cfg_at + 1] = 0xfe;
        assert!(decode_assign(&bad).is_err());
    }

    #[test]
    fn oversized_body_cannot_encode() {
        let h = FrameHeader::control(FrameKind::Packet, 0);
        let mut out = Vec::new();
        assert!(encode_header(&h, MAX_BODY_LEN + 1, &mut out).is_err());
        out.clear();
        assert!(encode_header(&h, MAX_BODY_LEN, &mut out).is_ok());
    }

    #[test]
    fn unregistered_kind_cannot_encode() {
        let p = Packet {
            msgs: vec![Msg {
                kind: "not_a_kind",
                payload: Payload::Vector(vec![]),
                cost: BitCost::zero(),
            }],
        };
        assert!(encode_packet(&p).is_err());
    }
}
