//! The message-kind registry: every `kind` tag a [`super::Msg`] may carry.
//!
//! The paper's claims rest on exact bit accounting, so the set of message
//! kinds that cross the wire is a *closed* vocabulary: each kind is declared
//! here once, with its direction and whether the simulated network charges
//! for it under the paper's accounting conventions. `repro audit`'s
//! bit-accounting rule cross-checks every `push_*("kind", …)` call site in
//! the codebase against this table (and the table against the call sites),
//! so a new message cannot be introduced without deciding — visibly, in one
//! place — whether its bits are charged. `docs/TRACING.md` documents the
//! same vocabulary for trace consumers; the audit's registry-sync rule keeps
//! the two in lockstep.
//!
//! `Charge::Free` marks framework messages that ride along uncharged by the
//! reference accounting (control bits, anchors the receiver already knows,
//! post-step gradients on refresh rounds). `Charge::Mixed` is for the rare
//! kind whose cost depends on the algorithm: `xi` is charged one bit by BL1
//! (the ξ schedule is client-observed state there) but rides free on BL2/BL3
//! rounds (where it duplicates information the participation draw already
//! paid for).

/// Which way a message kind travels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Server → client only.
    Down,
    /// Client → server only.
    Up,
    /// Used in both directions (e.g. `model`: broadcast by most servers,
    /// sent up by S-Local-GD clients on sync rounds).
    Both,
}

/// Whether the simulated network charges for a kind's payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Charge {
    /// Always carries a non-zero [`crate::compressors::BitCost`].
    Charged,
    /// Always pushed with exactly `BitCost::zero()` (framework ride-along).
    Free,
    /// Charged by some algorithms, free for others (documented per kind).
    Mixed,
}

/// One registered message kind.
#[derive(Clone, Copy, Debug)]
pub struct Kind {
    /// The tag passed to `Packet::push_*` and looked up by the receiver.
    pub name: &'static str,
    pub dir: Direction,
    pub charge: Charge,
}

/// The closed vocabulary of message kinds, sorted by name.
///
/// Keep entries in the `Kind { name: …, dir: …, charge: … }` literal form —
/// the audit's token scanner parses this table from source text so that
/// fixture crates can declare their own registries.
pub const KINDS: &[Kind] = &[
    // ADIANA's anchor-point broadcast (receiver reconstructs it; uncharged).
    Kind { name: "anchor", dir: Direction::Down, charge: Charge::Free },
    // S-Local-GD's synced model average.
    Kind { name: "avg", dir: Direction::Down, charge: Charge::Charged },
    // BL3's β_i / Δγ ride-along scalars (2 floats + 1 bit).
    Kind { name: "beta_gamma", dir: Direction::Up, charge: Charge::Charged },
    // NL1's compressed Hessian-coefficient update.
    Kind { name: "coeff_delta", dir: Direction::Up, charge: Charge::Charged },
    // S-Local-GD's sync/refresh control flags.
    Kind { name: "ctl", dir: Direction::Down, charge: Charge::Free },
    // Compressed gradient/model difference (DIANA, ADIANA, Artemis, DORE).
    Kind { name: "delta", dir: Direction::Up, charge: Charge::Charged },
    // DINGO's local Newton direction (aggregate-only; uncharged by
    // the reference accounting, which charges the hess_g round trip).
    Kind { name: "direction", dir: Direction::Up, charge: Charge::Free },
    // DINGO's gradient broadcast.
    Kind { name: "g", dir: Direction::Down, charge: Charge::Charged },
    // BL3's ξ-round gradient pair.
    Kind { name: "g1", dir: Direction::Up, charge: Charge::Charged },
    Kind { name: "g2", dir: Direction::Up, charge: Charge::Charged },
    // S-Local-GD's gradient mean on refresh rounds (framework message).
    Kind { name: "gbar", dir: Direction::Down, charge: Charge::Free },
    // Full local gradient (GD, NL1, DINGO line search).
    Kind { name: "grad", dir: Direction::Up, charge: Charge::Charged },
    // Compressed gradient coefficients (Newton, BL1 ξ-rounds).
    Kind { name: "grad_coeff", dir: Direction::Up, charge: Charge::Charged },
    // S-Local-GD's post-step gradient on refresh rounds: rides along
    // uncharged under the reference accounting (framework message).
    Kind { name: "grad_report", dir: Direction::Up, charge: Charge::Free },
    // BL2's ξ-round gradient at the shifted point.
    Kind { name: "grad_update", dir: Direction::Up, charge: Charge::Charged },
    // DINGO's H̃ᵀg broadcast (phase 2).
    Kind { name: "h_g", dir: Direction::Down, charge: Charge::Charged },
    // Newton's compressed Hessian coefficients.
    Kind { name: "hess_coeff", dir: Direction::Up, charge: Charge::Charged },
    // BL1/BL2/BL3's compressed Hessian-coefficient difference.
    Kind { name: "hess_delta", dir: Direction::Up, charge: Charge::Charged },
    // DINGO's [Hg; g] stack (2d floats).
    Kind { name: "hess_g", dir: Direction::Up, charge: Charge::Charged },
    // Model broadcast (most servers); S-Local-GD clients also send their
    // local model up on sync rounds.
    Kind { name: "model", dir: Direction::Both, charge: Charge::Charged },
    // BL1/BL2/BL3's compressed model update broadcast.
    Kind { name: "model_delta", dir: Direction::Down, charge: Charge::Charged },
    // DORE's compressed model residual broadcast.
    Kind { name: "model_residual", dir: Direction::Down, charge: Charge::Charged },
    // Artemis's compressed model update broadcast.
    Kind { name: "model_update", dir: Direction::Down, charge: Charge::Charged },
    // DINGO's line-search verdict flag (uncharged control bit).
    Kind { name: "proceed", dir: Direction::Down, charge: Charge::Free },
    // BL2's compression-error shift scalar.
    Kind { name: "shift_delta", dir: Direction::Up, charge: Charge::Charged },
    // DINGO's current iterate, re-broadcast for clients that already hold
    // it (uncharged framework message).
    Kind { name: "x", dir: Direction::Down, charge: Charge::Free },
    // DINGO's line-search trial point.
    Kind { name: "x_try", dir: Direction::Down, charge: Charge::Charged },
    // The ξ Bernoulli flag: BL1 charges 1 bit; BL2/BL3 ride it free.
    Kind { name: "xi", dir: Direction::Down, charge: Charge::Mixed },
];

/// Look up a kind by name.
pub fn find(name: &str) -> Option<&'static Kind> {
    KINDS.iter().find(|k| k.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_unique() {
        for w in KINDS.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
    }

    #[test]
    fn lookup() {
        assert_eq!(find("model").unwrap().charge, Charge::Charged);
        assert_eq!(find("ctl").unwrap().charge, Charge::Free);
        assert_eq!(find("xi").unwrap().charge, Charge::Mixed);
        assert_eq!(find("model").unwrap().dir, Direction::Both);
        assert!(find("warp").is_none());
    }
}
