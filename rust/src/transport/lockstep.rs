//! The in-process reference backend: clients execute serially on the
//! calling thread, borrowing the caller's local problems. This is the
//! semantics baseline — [`super::Threaded`] must match it bit for bit —
//! and the only backend usable with non-`Send` oracles (PJRT).

use super::{ClientStep, Downlink, PacketPool, Transport, Uplink};
use crate::obs::{Ctx, Lane, Obs};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use anyhow::{ensure, Context, Result};

/// Serial in-process transport.
pub struct Lockstep<'a> {
    locals: &'a [Box<dyn LocalProblem>],
    clients: Vec<Box<dyn ClientStep>>,
    rngs: Vec<Rng>,
    obs: Obs<'a>,
    pool: Option<PacketPool>,
}

impl<'a> Lockstep<'a> {
    /// `clients[i]` talks to `locals[i]` and draws from `rngs[i]`.
    pub fn new(
        locals: &'a [Box<dyn LocalProblem>],
        clients: Vec<Box<dyn ClientStep>>,
        rngs: Vec<Rng>,
    ) -> Self {
        assert_eq!(locals.len(), clients.len(), "locals/clients length mismatch");
        assert_eq!(rngs.len(), clients.len(), "rngs/clients length mismatch");
        Lockstep { locals, clients, rngs, obs: Obs::noop(), pool: None }
    }

    /// Attach a trace recorder: each client's `compute` is timed on its
    /// own `client:<i>` lane.
    pub fn with_obs(mut self, obs: Obs<'a>) -> Self {
        self.obs = obs;
        self
    }

    /// Attach a packet pool: downlinks are recycled once consumed and the
    /// reply batch draws from the pool's free lists.
    pub fn with_pool(mut self, pool: Option<PacketPool>) -> Self {
        self.pool = pool;
        self
    }
}

impl Transport for Lockstep<'_> {
    fn exchange(
        &mut self,
        round: usize,
        exchange: usize,
        mut sends: Vec<(usize, Downlink)>,
    ) -> Result<Vec<(usize, Uplink)>> {
        let mut replies = match &self.pool {
            Some(pool) => pool.batch(sends.len()),
            None => Vec::with_capacity(sends.len()),
        };
        for (i, down) in sends.drain(..) {
            ensure!(i < self.clients.len(), "no client {i}");
            let _span = self.obs.span("compute", Lane::Client(i), Ctx::client(round, exchange, i));
            let up = self
                .clients[i]
                .compute(self.locals[i].as_ref(), round, exchange, &down, &mut self.rngs[i])
                .with_context(|| format!("client {i}, round {round}"))?;
            replies.push((i, up));
            if let Some(pool) = &self.pool {
                pool.recycle_packet(down);
            }
        }
        if let Some(pool) = &self.pool {
            pool.recycle_batch(sends);
        }
        Ok(replies)
    }
}
