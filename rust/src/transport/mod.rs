//! The message-passing transport layer: what actually crosses the wire.
//!
//! # Architecture
//!
//! Every federated method is split into a server half
//! ([`crate::coordinator::ServerState`]) and a per-client half
//! ([`ClientStep`]). One communication round is a sequence of *exchanges*;
//! each exchange is
//!
//! ```text
//! server  ── plan ──▶  Downlink per addressed client
//! client  ─ compute ─▶ Uplink  (runs concurrently under Threaded)
//! server  ── absorb ─▶ state update, next exchange or end of round
//! ```
//!
//! Most methods use one exchange per round (plus a broadcast-only second
//! exchange for bidirectionally-compressed methods); DINGO's line search
//! uses one exchange per gradient round trip. Messages are materialized as
//! [`Packet`]s of typed [`Msg`]s — compressed vectors/matrices, scalar
//! ride-alongs, flag bits — each carrying its exact
//! [`crate::compressors::BitCost`]. The round loop derives the per-round
//! communication tally by summing the costs of the packets that actually
//! crossed, so bit accounting can no longer drift from the message flow.
//!
//! # Message types
//!
//! | payload              | used for                                        |
//! |----------------------|-------------------------------------------------|
//! | [`Payload::Vector`]  | gradients, models, compressed model deltas      |
//! | [`Payload::Matrix`]  | compressed Hessian-coefficient differences      |
//! | [`Payload::Scalars`] | shift/β/γ ride-alongs                           |
//! | [`Payload::Flags`]   | ξ bits, sync/refresh control bits               |
//!
//! A [`Msg`] has a `kind` tag so the receiving half looks fields up by name
//! rather than by fragile positional index; a kind that is absent (e.g. the
//! gradient coefficients on a ξ = 0 round) is simply not pushed.
//!
//! # Backend matrix
//!
//! | backend              | clients run     | local problems      | use case |
//! |----------------------|-----------------|---------------------|----------|
//! | [`Lockstep`]         | serially, in-process | borrowed (any, incl. non-`Send` PJRT oracles) | reference semantics, tests, PJRT |
//! | [`Threaded`]         | concurrently on a scoped worker pool | rebuilt per worker from a [`ProblemFactory`] | multi-core simulation |
//! | [`Tcp`]              | concurrently, one scoped thread + loopback socket per worker | rebuilt per worker from a [`ProblemFactory`] | real-socket federation (bytes on the wire) |
//! | [`Tcp`] via [`TcpServer`] | in standalone `repro worker` processes dialing a listening round loop | rebuilt per process from the `Assign` handshake's data recipe | multi-host federation (`crate::coordinator::remote`) |
//!
//! # Determinism guarantee
//!
//! All backends produce **bit-identical** [`crate::metrics::History`]
//! traces (enforced for every [`crate::config::Algorithm`] by
//! `tests/transport_equivalence.rs`):
//!
//! * server-side randomness (participation sampling, ξ schedules, model
//!   broadcast compression) draws from the single run stream
//!   `Rng::new(cfg.seed)`, exactly as the pre-transport coordinator did and
//!   in the same order — so configurations whose client-side compressors
//!   are deterministic (Top-K, Rank-R, identity: every figure/table BL
//!   configuration) reproduce the pre-refactor trajectories bit for bit;
//! * client-side randomness (stochastic compressors) draws from per-client
//!   streams split off the run seed via [`client_rngs`] /
//!   [`crate::rng::Rng::derive`], owned by the client for the whole run —
//!   so results cannot depend on scheduling order, only on the client
//!   index. (This is the one intentional behavior change of the transport
//!   refactor: configurations with *stochastic client-side* compressors
//!   draw from split streams instead of the old shared interleaved stream —
//!   same distribution, different samples.)
//!
//! [`Threaded`] routes each client to a fixed worker, collects the round's
//! uplinks, and sorts them by client index before the server absorbs them,
//! so the absorb order is identical to [`Lockstep`]'s.
//!
//! # Wire layers
//!
//! A backend may move either *structs* (the in-process fast path above) or
//! *bytes*, through two further layers:
//!
//! * [`codec`] — the canonical, versioned binary encoding of [`Packet`]s
//!   (`encode_packet`/`decode_packet`; exact f64 bit patterns, so costs and
//!   payloads round-trip bit-for-bit). Frame layout: `docs/WIRE.md`.
//! * [`session`] — framed, length-prefixed streams over any
//!   `Read + Write` transport, with per-exchange sequencing headers.
//!
//! [`Tcp`] stacks the two over loopback sockets; because the codec is
//! exact, the tally the round loop derives from *decoded* frames is
//! bit-identical to the in-process one, and `tests/transport_equivalence.rs`
//! holds all three backends to the same [`crate::metrics::History`].

pub mod codec;
pub mod kinds;
mod lockstep;
pub mod session;
mod tcp;
mod threaded;
pub(crate) mod worker;

pub use lockstep::Lockstep;
pub use tcp::{Tcp, TcpServer};
pub use threaded::Threaded;

use crate::compressors::BitCost;
use crate::linalg::{Mat, Vector};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// One typed message payload (see the module table).
#[derive(Clone, Debug)]
pub enum Payload {
    /// Dense float vector (gradient, model, compressed model delta, ...).
    Vector(Vector),
    /// Coefficient matrix (compressed Hessian difference, ...).
    Matrix(Mat),
    /// A few scalar ride-alongs (shift diffs, β, γ, ...).
    Scalars(Vec<f64>),
    /// Control bits (ξ, sync/refresh flags, ...).
    Flags(Vec<bool>),
}

/// One message: a tagged payload plus its exact wire cost.
///
/// `cost` is what the simulated network charges — it is *not* derived from
/// the payload size, because compressed payloads travel in their decoded
/// form (e.g. a Top-K difference matrix is dense with zeros but costs
/// `K` floats + `K` indices), and some framework messages ride along
/// uncharged under the paper's accounting conventions (`BitCost::zero`).
#[derive(Clone, Debug)]
pub struct Msg {
    pub kind: &'static str,
    pub payload: Payload,
    pub cost: BitCost,
}

/// An ordered bundle of messages travelling in one direction of one
/// exchange. [`Downlink`]/[`Uplink`] name the two directions.
#[derive(Clone, Debug, Default)]
pub struct Packet {
    pub msgs: Vec<Msg>,
}

/// Server → client packet.
pub type Downlink = Packet;
/// Client → server packet.
pub type Uplink = Packet;

impl Packet {
    /// An empty packet (a zero-cost "go" trigger).
    pub fn empty() -> Packet {
        Packet::default()
    }

    pub fn is_empty(&self) -> bool {
        self.msgs.is_empty()
    }

    /// Total wire cost of the packet.
    pub fn cost(&self) -> BitCost {
        let mut c = BitCost::zero();
        for m in &self.msgs {
            c += m.cost;
        }
        c
    }

    pub fn push_vector(&mut self, kind: &'static str, v: Vector, cost: BitCost) {
        self.msgs.push(Msg { kind, payload: Payload::Vector(v), cost });
    }

    pub fn push_matrix(&mut self, kind: &'static str, m: Mat, cost: BitCost) {
        self.msgs.push(Msg { kind, payload: Payload::Matrix(m), cost });
    }

    pub fn push_scalars(&mut self, kind: &'static str, s: Vec<f64>, cost: BitCost) {
        self.msgs.push(Msg { kind, payload: Payload::Scalars(s), cost });
    }

    pub fn push_flags(&mut self, kind: &'static str, f: Vec<bool>, cost: BitCost) {
        self.msgs.push(Msg { kind, payload: Payload::Flags(f), cost });
    }

    fn find(&self, kind: &str) -> Option<&Payload> {
        self.msgs.iter().find(|m| m.kind == kind).map(|m| &m.payload)
    }

    /// Whether a message of this kind is present.
    pub fn has(&self, kind: &str) -> bool {
        self.find(kind).is_some()
    }

    /// The vector message tagged `kind` (error if absent or mistyped —
    /// both halves of a method are written together, so this is a protocol
    /// bug, not a runtime condition).
    pub fn vector(&self, kind: &str) -> Result<&[f64]> {
        match self.find(kind) {
            Some(Payload::Vector(v)) => Ok(v),
            Some(_) => bail!("message '{kind}' is not a vector"),
            None => bail!("missing vector message '{kind}'"),
        }
    }

    /// The vector tagged `kind` if present (for ξ-conditional messages).
    pub fn vector_opt(&self, kind: &str) -> Result<Option<&[f64]>> {
        match self.find(kind) {
            Some(Payload::Vector(v)) => Ok(Some(v)),
            Some(_) => bail!("message '{kind}' is not a vector"),
            None => Ok(None),
        }
    }

    /// The matrix message tagged `kind`.
    pub fn matrix(&self, kind: &str) -> Result<&Mat> {
        match self.find(kind) {
            Some(Payload::Matrix(m)) => Ok(m),
            Some(_) => bail!("message '{kind}' is not a matrix"),
            None => bail!("missing matrix message '{kind}'"),
        }
    }

    /// The scalar list tagged `kind`.
    pub fn scalars(&self, kind: &str) -> Result<&[f64]> {
        match self.find(kind) {
            Some(Payload::Scalars(s)) => Ok(s),
            Some(_) => bail!("message '{kind}' is not a scalar list"),
            None => bail!("missing scalar message '{kind}'"),
        }
    }

    /// The flag list tagged `kind`.
    pub fn flags(&self, kind: &str) -> Result<&[bool]> {
        match self.find(kind) {
            Some(Payload::Flags(f)) => Ok(f),
            Some(_) => bail!("message '{kind}' is not a flag list"),
            None => bail!("missing flag message '{kind}'"),
        }
    }
}

/// Shared free-list recycler for the per-round wire objects: payload
/// buffers, message lists, and send/reply batches.
///
/// Algorithms that opt in (via [`crate::coordinator::ServerState::pool`])
/// acquire payload storage here instead of allocating, and the round loop /
/// [`Lockstep`] backend return packets to the pool once they have been
/// absorbed. After the warm-up round has populated the free lists, the
/// steady-state exchange path performs **zero heap allocations** (asserted
/// by `tests/alloc_regression.rs` for BL1 and FedNL).
///
/// Cheap to clone (an `Arc` handle); the mutex is uncontended under
/// [`Lockstep`] and held only for short free-list operations under
/// [`Threaded`]. Locking and `Arc` cloning do not allocate.
#[derive(Clone, Default)]
pub struct PacketPool {
    inner: Arc<Mutex<PoolInner>>,
}

#[derive(Default)]
struct PoolInner {
    floats: Vec<Vec<f64>>,
    flags: Vec<Vec<bool>>,
    msgs: Vec<Vec<Msg>>,
    batches: Vec<Vec<(usize, Packet)>>,
}

/// Take the first spare with enough capacity, or `None`. Unfit spares stay
/// pooled — buffers of different roles (length `d`, `d²`, `n`) coexist and
/// each acquire finds its own size class after warm-up.
fn take_fit<T>(list: &mut Vec<Vec<T>>, capacity: usize) -> Option<Vec<T>> {
    let pos = list.iter().position(|v| v.capacity() >= capacity)?;
    let mut v = list.swap_remove(pos);
    v.clear();
    Some(v)
}

impl PacketPool {
    pub fn new() -> Self {
        PacketPool::default()
    }

    /// An empty float buffer with at least `capacity` spare capacity
    /// (recycled if possible, freshly allocated during warm-up).
    pub fn vec_f64(&self, capacity: usize) -> Vec<f64> {
        // audit:allow(panic-safety): mutex poisoning only follows a panic on another thread; propagating the poison panic is the correct response.
        let mut inner = self.inner.lock().unwrap();
        take_fit(&mut inner.floats, capacity).unwrap_or_else(|| Vec::with_capacity(capacity))
    }

    /// An empty flag buffer with at least `capacity` spare capacity.
    pub fn vec_bool(&self, capacity: usize) -> Vec<bool> {
        // audit:allow(panic-safety): mutex poisoning only follows a panic on another thread; propagating the poison panic is the correct response.
        let mut inner = self.inner.lock().unwrap();
        take_fit(&mut inner.flags, capacity).unwrap_or_else(|| Vec::with_capacity(capacity))
    }

    /// An empty packet whose message list is recycled if possible.
    pub fn packet(&self) -> Packet {
        // audit:allow(panic-safety): mutex poisoning only follows a panic on another thread; propagating the poison panic is the correct response.
        let mut inner = self.inner.lock().unwrap();
        match take_fit(&mut inner.msgs, 0) {
            Some(msgs) => Packet { msgs },
            None => Packet::empty(),
        }
    }

    /// An empty send/reply batch with at least `capacity` spare capacity.
    pub fn batch(&self, capacity: usize) -> Vec<(usize, Packet)> {
        // audit:allow(panic-safety): mutex poisoning only follows a panic on another thread; propagating the poison panic is the correct response.
        let mut inner = self.inner.lock().unwrap();
        take_fit(&mut inner.batches, capacity).unwrap_or_else(|| Vec::with_capacity(capacity))
    }

    /// An all-zeros `rows × cols` matrix backed by pooled storage.
    pub fn zeros_mat(&self, rows: usize, cols: usize) -> Mat {
        let mut data = self.vec_f64(rows * cols);
        data.resize(rows * cols, 0.0);
        Mat::from_vec(rows, cols, data)
    }

    /// A pooled deep copy of a matrix (same shape and values).
    pub fn clone_mat(&self, src: &Mat) -> Mat {
        let mut data = self.vec_f64(src.rows() * src.cols());
        data.extend_from_slice(src.data());
        Mat::from_vec(src.rows(), src.cols(), data)
    }

    /// A pooled deep copy of a float slice.
    pub fn clone_slice(&self, src: &[f64]) -> Vec<f64> {
        let mut v = self.vec_f64(src.len());
        v.extend_from_slice(src);
        v
    }

    /// A pooled deep copy of a packet (same kinds, values, and costs).
    pub fn clone_packet(&self, src: &Packet) -> Packet {
        let mut out = self.packet();
        for m in &src.msgs {
            let payload = match &m.payload {
                Payload::Vector(v) => Payload::Vector(self.clone_slice(v)),
                Payload::Matrix(a) => Payload::Matrix(self.clone_mat(a)),
                Payload::Scalars(s) => Payload::Scalars(self.clone_slice(s)),
                Payload::Flags(f) => {
                    let mut nf = self.vec_bool(f.len());
                    nf.extend_from_slice(f);
                    Payload::Flags(nf)
                }
            };
            out.msgs.push(Msg { kind: m.kind, payload, cost: m.cost });
        }
        out
    }

    /// Return a packet's buffers to the free lists.
    pub fn recycle_packet(&self, mut p: Packet) {
        // audit:allow(panic-safety): mutex poisoning only follows a panic on another thread; propagating the poison panic is the correct response.
        let mut inner = self.inner.lock().unwrap();
        for m in p.msgs.drain(..) {
            match m.payload {
                Payload::Vector(v) | Payload::Scalars(v) => inner.floats.push(v),
                Payload::Matrix(a) => inner.floats.push(a.into_vec()),
                Payload::Flags(f) => inner.flags.push(f),
            }
        }
        inner.msgs.push(p.msgs);
    }

    /// Return a whole send/reply batch (packets and the batch vector itself).
    pub fn recycle_batch(&self, mut batch: Vec<(usize, Packet)>) {
        for (_, p) in batch.drain(..) {
            self.recycle_packet(p);
        }
        // audit:allow(panic-safety): mutex poisoning only follows a panic on another thread; propagating the poison panic is the correct response.
        let mut inner = self.inner.lock().unwrap();
        inner.batches.push(batch);
    }
}

/// The client half of a federated method: per-exchange local work.
///
/// Implementations own all per-client state (model mirrors, learned
/// coefficients, compressors, scratch). `Send` is required so the
/// [`Threaded`] backend can move the state onto a worker thread; the local
/// problem itself is *not* `Send` and is therefore passed in by the
/// backend each call (borrowed under [`Lockstep`], worker-owned under
/// [`Threaded`]).
pub trait ClientStep: Send {
    /// Handle one exchange: receive `down`, do local work (oracle calls,
    /// basis projection, compression — the dominant per-round cost), reply.
    ///
    /// `rng` is this client's private stream for the whole run; stochastic
    /// compression must draw from it and nothing else.
    fn compute(
        &mut self,
        local: &dyn LocalProblem,
        round: usize,
        exchange: usize,
        down: &Downlink,
        rng: &mut Rng,
    ) -> Result<Uplink>;
}

/// Builds client `i`'s local problem. The [`Threaded`] backend calls this
/// once per client *on the owning worker thread*, because
/// [`LocalProblem`] is deliberately non-`Send` (PJRT handles).
pub type ProblemFactory<'a> = &'a (dyn Fn(usize) -> Box<dyn LocalProblem> + Sync);

/// A transport backend: executes one exchange of one round.
pub trait Transport {
    /// Deliver each `(client, downlink)` pair, run the addressed clients'
    /// [`ClientStep::compute`], and return `(client, uplink)` replies
    /// **sorted by client index** (callers send in ascending order; replies
    /// come back in ascending order regardless of scheduling).
    fn exchange(
        &mut self,
        round: usize,
        exchange: usize,
        sends: Vec<(usize, Downlink)>,
    ) -> Result<Vec<(usize, Uplink)>>;
}

/// Per-client RNG streams for one run: client `i` owns
/// `Rng::new(seed).derive(i)` for the run's whole lifetime. A pure
/// function of `(seed, i)` — independent of backend and scheduling.
pub fn client_rngs(seed: u64, n: usize) -> Vec<Rng> {
    let root = Rng::new(seed);
    (0..n).map(|i| root.derive(i as u64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packet_cost_sums_messages() {
        let mut p = Packet::empty();
        assert!(p.is_empty());
        assert_eq!(p.cost(), BitCost::zero());
        p.push_vector("g", vec![1.0, 2.0], BitCost::floats(2));
        p.push_flags("xi", vec![true], BitCost::bits(1.0));
        let c = p.cost();
        assert_eq!(c.floats, 2.0);
        assert_eq!(c.aux_bits, 1.0);
        assert!(!p.is_empty());
    }

    #[test]
    fn packet_lookup_by_kind_and_type() {
        let mut p = Packet::empty();
        p.push_vector("v", vec![3.0], BitCost::zero());
        p.push_matrix("m", Mat::zeros(2, 2), BitCost::zero());
        p.push_scalars("s", vec![0.5, 0.25], BitCost::zero());
        p.push_flags("f", vec![false, true], BitCost::zero());
        assert_eq!(p.vector("v").unwrap(), &[3.0]);
        assert_eq!(p.matrix("m").unwrap().rows(), 2);
        assert_eq!(p.scalars("s").unwrap(), &[0.5, 0.25]);
        assert_eq!(p.flags("f").unwrap(), &[false, true]);
        assert!(p.has("v") && !p.has("w"));
        // Absent and mistyped lookups are protocol errors…
        assert!(p.vector("w").is_err());
        assert!(p.matrix("v").is_err());
        assert!(p.scalars("f").is_err());
        assert!(p.flags("s").is_err());
        // …except the explicitly optional form.
        assert!(p.vector_opt("w").unwrap().is_none());
        assert_eq!(p.vector_opt("v").unwrap().unwrap(), &[3.0]);
        assert!(p.vector_opt("m").is_err());
    }

    #[test]
    fn client_streams_are_reproducible_and_distinct() {
        let a = client_rngs(7, 4);
        let b = client_rngs(7, 4);
        for (x, y) in a.iter().zip(&b) {
            let (mut x, mut y) = (x.clone(), y.clone());
            for _ in 0..16 {
                assert_eq!(x.next_u64(), y.next_u64());
            }
        }
        let mut c0 = a[0].clone();
        let mut c1 = a[1].clone();
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert!(same < 2, "client streams must be independent");
    }
}
