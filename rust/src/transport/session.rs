//! The session layer: framed, length-prefixed packet streams.
//!
//! A [`Session`] wraps any `Read + Write` byte stream (a `TcpStream` in
//! production, an in-memory cursor in tests) and moves whole frames:
//! a fixed [`codec::HEADER_LEN`]-byte header ([`codec::FrameHeader`])
//! followed by `body_len` body bytes. This is the boundary between the two
//! transport modes described in [`super`]'s module docs — backends either
//! hand [`Packet`] structs across directly (in-process fast path) or drive
//! a `Session` per connection (byte path, [`super::Tcp`]).
//!
//! The encode scratch buffer is owned by the session and reused across
//! sends, so steady-state framing costs one `write_all` per frame and no
//! allocation once the buffer has grown to the round's packet size.

use super::codec::{self, Assignment, FrameHeader, FrameKind};
use super::Packet;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// What [`Session::recv`] yielded: the decoded body of one frame.
#[derive(Debug)]
pub enum FramePayload {
    /// A [`FrameKind::Packet`] frame's decoded packet.
    Packet(Packet),
    /// A [`FrameKind::Error`] frame's message (a remote failure report).
    Error(String),
    /// An [`FrameKind::Assign`] frame's decoded run assignment (the
    /// assigned worker index is in the frame header's `client` field).
    Assign(Assignment),
    /// A bodyless control frame ([`FrameKind::Hello`] / [`FrameKind::Bye`]
    /// / [`FrameKind::Join`]).
    Control(FrameKind),
}

/// One framed byte stream: owns the stream and a reusable encode buffer.
pub struct Session<S> {
    stream: S,
    scratch: Vec<u8>,
}

impl<S: Read + Write> Session<S> {
    pub fn new(stream: S) -> Self {
        Session { stream, scratch: Vec::new() }
    }

    /// Borrow the underlying stream (to adjust socket options, or to shut
    /// a TCP connection down out from under a blocked reader).
    pub fn stream_ref(&self) -> &S {
        &self.stream
    }

    /// Take the stream back out of the session (handing a handshake-phase
    /// connection over to the round-loop machinery).
    pub fn into_inner(self) -> S {
        self.stream
    }

    /// Frame and send one packet under the given header (the header's
    /// `kind` is forced to [`FrameKind::Packet`] by construction at the
    /// call sites; any kind is legal on the wire).
    pub fn send_packet(&mut self, header: &FrameHeader, packet: &Packet) -> Result<()> {
        self.scratch.clear();
        codec::encode_packet_into(packet, &mut self.scratch).context("encoding packet body")?;
        let mut head = Vec::with_capacity(codec::HEADER_LEN);
        codec::encode_header(header, self.scratch.len(), &mut head)?;
        self.stream.write_all(&head).context("writing frame header")?;
        self.stream.write_all(&self.scratch).context("writing frame body")?;
        self.stream.flush().context("flushing frame")?;
        Ok(())
    }

    /// Send a bodyless control frame ([`FrameKind::Hello`]/[`FrameKind::Bye`]).
    pub fn send_control(&mut self, kind: FrameKind, client: usize) -> Result<()> {
        let mut head = Vec::with_capacity(codec::HEADER_LEN);
        codec::encode_header(&FrameHeader::control(kind, client), 0, &mut head)?;
        self.stream.write_all(&head).context("writing control frame")?;
        self.stream.flush().context("flushing control frame")?;
        Ok(())
    }

    /// Send an [`FrameKind::Assign`] frame carrying the run assignment for
    /// worker `worker` (the index rides in the header's `client` field).
    pub fn send_assign(&mut self, worker: usize, assignment: &Assignment) -> Result<()> {
        self.scratch.clear();
        codec::encode_assign(assignment, &mut self.scratch)
            .context("encoding assignment body")?;
        let mut head = Vec::with_capacity(codec::HEADER_LEN);
        let h = FrameHeader::control(FrameKind::Assign, worker);
        codec::encode_header(&h, self.scratch.len(), &mut head)?;
        self.stream.write_all(&head).context("writing assignment header")?;
        self.stream.write_all(&self.scratch).context("writing assignment body")?;
        self.stream.flush().context("flushing assignment frame")?;
        Ok(())
    }

    /// Report a failure to the peer: an [`FrameKind::Error`] frame whose
    /// body is the UTF-8 message, re-using the failed exchange's header
    /// coordinates so the receiver can attribute it.
    pub fn send_error(&mut self, header: &FrameHeader, msg: &str) -> Result<()> {
        let body = msg.as_bytes();
        let mut head = Vec::with_capacity(codec::HEADER_LEN);
        let h = FrameHeader { kind: FrameKind::Error, ..*header };
        codec::encode_header(&h, body.len(), &mut head)?;
        self.stream.write_all(&head).context("writing error frame header")?;
        self.stream.write_all(body).context("writing error frame body")?;
        self.stream.flush().context("flushing error frame")?;
        Ok(())
    }

    /// Block until one whole frame arrives; decode header and body.
    /// Stream EOF, short reads and undecodable bytes are all errors.
    pub fn recv(&mut self) -> Result<(FrameHeader, FramePayload)> {
        let mut head = [0u8; codec::HEADER_LEN];
        self.stream.read_exact(&mut head).context("reading frame header")?;
        let (header, body_len) = codec::decode_header(&head)?;
        // The length field is peer-controlled: reject absurd values before
        // the resize below allocates (a hostile header must be a decode
        // error, never a multi-GiB allocation or OOM abort).
        if body_len > codec::MAX_BODY_LEN {
            bail!(
                "frame body length {body_len} exceeds MAX_BODY_LEN ({}) — \
                 corrupt or hostile header",
                codec::MAX_BODY_LEN
            );
        }
        self.scratch.clear();
        self.scratch.resize(body_len, 0);
        self.stream.read_exact(&mut self.scratch).with_context(|| {
            format!("reading {body_len}-byte body of a {:?} frame", header.kind)
        })?;
        let payload = match header.kind {
            FrameKind::Packet => FramePayload::Packet(
                codec::decode_packet(&self.scratch).context("decoding packet body")?,
            ),
            FrameKind::Error => {
                FramePayload::Error(String::from_utf8_lossy(&self.scratch).into_owned())
            }
            FrameKind::Assign => FramePayload::Assign(
                codec::decode_assign(&self.scratch).context("decoding assignment body")?,
            ),
            kind => {
                if body_len != 0 {
                    bail!("{kind:?} frame carries an unexpected {body_len}-byte body");
                }
                FramePayload::Control(kind)
            }
        };
        Ok((header, payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::BitCost;
    use std::io::Cursor;

    /// A loopback stream: writes append to an owned buffer, reads consume it.
    struct Loopback(Cursor<Vec<u8>>);

    impl Read for Loopback {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.0.read(buf)
        }
    }

    impl Write for Loopback {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let pos = self.0.position();
            self.0.set_position(self.0.get_ref().len() as u64);
            let n = self.0.write(buf)?;
            self.0.set_position(pos);
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn loopback() -> Session<Loopback> {
        Session::new(Loopback(Cursor::new(Vec::new())))
    }

    #[test]
    fn packet_frames_round_trip_in_order() {
        let mut s = loopback();
        let mut p1 = Packet::empty();
        p1.push_vector("model", vec![1.5, -0.0], BitCost::floats(2));
        let mut p2 = Packet::empty();
        p2.push_flags("xi", vec![true], BitCost::bits(1.0));
        s.send_packet(&FrameHeader::packet(3, 0, 1), &p1).unwrap();
        s.send_packet(&FrameHeader::packet(3, 1, 4), &p2).unwrap();

        let (h1, f1) = s.recv().unwrap();
        assert_eq!(h1, FrameHeader::packet(3, 0, 1));
        match f1 {
            FramePayload::Packet(p) => {
                assert_eq!(p.vector("model").unwrap(), &[1.5, -0.0]);
                assert_eq!(p.vector("model").unwrap()[1].to_bits(), (-0.0f64).to_bits());
            }
            other => panic!("expected packet, got {other:?}"),
        }
        let (h2, f2) = s.recv().unwrap();
        assert_eq!((h2.round, h2.exchange, h2.client), (3, 1, 4));
        assert!(matches!(f2, FramePayload::Packet(p) if p.flags("xi").unwrap() == [true]));
    }

    #[test]
    fn control_and_error_frames() {
        let mut s = loopback();
        s.send_control(FrameKind::Hello, 7).unwrap();
        s.send_error(&FrameHeader::packet(2, 0, 5), "client 5 exploded").unwrap();
        s.send_control(FrameKind::Bye, 0).unwrap();

        let (h, f) = s.recv().unwrap();
        assert_eq!(h.client, 7);
        assert!(matches!(f, FramePayload::Control(FrameKind::Hello)));
        let (h, f) = s.recv().unwrap();
        assert_eq!((h.round, h.client), (2, 5));
        assert!(matches!(f, FramePayload::Error(m) if m == "client 5 exploded"));
        let (_, f) = s.recv().unwrap();
        assert!(matches!(f, FramePayload::Control(FrameKind::Bye)));
    }

    #[test]
    fn join_and_assign_frames_round_trip() {
        let mut s = loopback();
        s.send_control(FrameKind::Join, 0).unwrap();
        let a = Assignment {
            fingerprint: 42,
            workers: 2,
            clients: 5,
            config: "algorithm=bl1".into(),
            recipe: "synth n=5".into(),
        };
        s.send_assign(1, &a).unwrap();
        let (_, f) = s.recv().unwrap();
        assert!(matches!(f, FramePayload::Control(FrameKind::Join)));
        let (h, f) = s.recv().unwrap();
        assert_eq!(h.client, 1, "assigned worker index rides in the header");
        assert!(matches!(f, FramePayload::Assign(got) if got == a));
    }

    #[test]
    fn eof_and_garbage_are_errors() {
        let mut empty = loopback();
        assert!(empty.recv().is_err(), "EOF must not parse as a frame");
        let mut garbage = Session::new(Loopback(Cursor::new(vec![0u8; 64])));
        assert!(garbage.recv().is_err(), "zero bytes must not parse as a frame");
    }
}
