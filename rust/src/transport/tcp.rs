//! The real-socket backend: every exchange crosses TCP loopback as bytes.
//!
//! Layout: one listener per round loop, one connection per worker thread
//! (client `i` is pinned to worker `i % workers`, exactly like
//! [`super::Threaded`]). Downlinks are encoded by [`super::codec`], framed
//! by [`super::session::Session`], written to the worker's socket, decoded
//! on the worker, computed, and the uplink comes back the same way — so the
//! server-side [`crate::coordinator::CommTally`] is derived from packets
//! that were *actually serialized and decoded*, and the codec's exact f64
//! round-trip is what keeps the tally (and the whole
//! [`crate::metrics::History`]) bit-identical to the in-process backends
//! (`tests/transport_equivalence.rs`).
//!
//! Deadlock freedom: the server writes every downlink of an exchange before
//! reading any uplink, so a worker must never be the reason a downlink
//! write blocks. Each worker therefore runs a dedicated reader thread that
//! eagerly drains its socket into an in-process channel; compute happens
//! behind that buffer. Uplink writes can block at worst until the server
//! finishes its (bounded) downlink writes and starts reading.
//!
//! Sequencing: every frame carries `(round, exchange, client)` and the
//! server verifies them against its expectation on receipt — a misrouted or
//! stale frame is an immediate error, never silent state corruption.
//! Replies are read per-connection in the order the downlinks were written
//! (workers are single-threaded and FIFO), then sorted by client index, so
//! the absorb order is identical to [`super::Lockstep`].
//!
//! Tracing: each client's work still emits its `compute` span (on the
//! worker, client lane) and the round loop's `bits` events are emitted by
//! the coordinator from the same decoded packets the server absorbs, so a
//! traced TCP run validates like any other (`python/analysis/load_trace.py`).

use super::codec::{FrameHeader, FrameKind};
use super::session::{FramePayload, Session};
use super::threaded::panic_message;
use super::{ClientStep, Downlink, ProblemFactory, Transport, Uplink};
use crate::obs::{Ctx, Lane, Obs};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread::Scope;
use std::time::Duration;

/// How long the server waits for all workers to connect and greet before
/// declaring the round loop dead (covers a worker that failed to spawn).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// One client pinned to a worker: index, state, private RNG stream.
type ClientSlot = (usize, Box<dyn ClientStep>, Rng);

/// The server half: one framed connection per worker. Created by
/// [`Tcp::spawn`] inside a [`std::thread::scope`]; dropping it sends `Bye`
/// on every connection so the scoped workers shut down and join.
pub struct Tcp {
    /// Connection `w` serves the clients of residue class `w`.
    conns: Vec<Session<TcpStream>>,
    workers: usize,
}

impl Tcp {
    /// Bind a loopback listener, spawn `workers` scoped client threads that
    /// connect back to it, and complete the `Hello` handshake with each.
    /// Worker `w` owns the client states (and factory-built local problems)
    /// of residue class `w`, exactly like [`super::Threaded`].
    pub fn spawn<'scope, 'env: 'scope>(
        scope: &'scope Scope<'scope, 'env>,
        workers: usize,
        clients: Vec<Box<dyn ClientStep>>,
        rngs: Vec<Rng>,
        factory: ProblemFactory<'env>,
        obs: Obs<'env>,
    ) -> Result<Tcp> {
        assert_eq!(clients.len(), rngs.len(), "rngs/clients length mismatch");
        let workers = workers.clamp(1, clients.len().max(1));
        let listener =
            TcpListener::bind(("127.0.0.1", 0)).context("binding the loopback listener")?;
        let addr = listener.local_addr().context("reading the listener address")?;
        let mut parts: Vec<Vec<ClientSlot>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, (c, r)) in clients.into_iter().zip(rngs).enumerate() {
            parts[i % workers].push((i, c, r));
        }
        for (w, part) in parts.into_iter().enumerate() {
            scope.spawn(move || {
                if let Err(e) = worker_main(addr, w, part, factory, obs) {
                    // The server sees the broken/missing connection and
                    // fails the exchange; this is diagnostics, not control.
                    eprintln!("tcp transport worker {w}: {e:#}");
                }
            });
        }
        let conns = accept_workers(&listener, workers)?;
        Ok(Tcp { conns, workers })
    }
}

/// Accept until every worker has connected and said `Hello` (the header's
/// `client` field carries the worker index), or the handshake deadline
/// passes. Nonblocking accept + poll so a dead worker cannot hang the run.
fn accept_workers(listener: &TcpListener, workers: usize) -> Result<Vec<Session<TcpStream>>> {
    listener.set_nonblocking(true).context("making the listener nonblocking")?;
    // audit:allow(determinism-clock): wall-clock here only bounds the connection handshake; no run result depends on it.
    let deadline = std::time::Instant::now() + HANDSHAKE_TIMEOUT;
    let mut conns: Vec<Option<Session<TcpStream>>> = (0..workers).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < workers {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).context("restoring blocking mode")?;
                stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                // Bound the greeting read too, then return to fully
                // blocking reads for the round loop.
                stream
                    .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
                    .context("setting the handshake read timeout")?;
                let mut sess = Session::new(stream);
                let (hdr, payload) = sess.recv().context("reading a worker greeting")?;
                if !matches!(payload, FramePayload::Control(FrameKind::Hello)) {
                    bail!("expected a Hello greeting, got a {:?} frame", hdr.kind);
                }
                let w = hdr.client as usize;
                if w >= workers || conns[w].is_some() {
                    bail!("invalid or duplicate worker greeting (worker {w} of {workers})");
                }
                conns[w] = Some(sess);
                connected += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // audit:allow(determinism-clock): wall-clock here only bounds the connection handshake; no run result depends on it.
                if std::time::Instant::now() >= deadline {
                    bail!("timed out waiting for {} of {workers} workers", workers - connected);
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e).context("accepting a worker connection"),
        }
    }
    let mut out = Vec::with_capacity(workers);
    for sess in conns.into_iter().flatten() {
        let stream_ref = sess.stream_ref();
        stream_ref.set_read_timeout(None).context("clearing the handshake read timeout")?;
        out.push(sess);
    }
    Ok(out)
}

/// One worker thread: connect, greet, build local problems, then serve
/// decoded downlinks until `Bye` (or the connection drops).
fn worker_main(
    addr: std::net::SocketAddr,
    w: usize,
    part: Vec<ClientSlot>,
    factory: ProblemFactory<'_>,
    obs: Obs<'_>,
) -> Result<()> {
    let stream = TcpStream::connect(addr).context("connecting to the round loop")?;
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    let reader_stream = stream.try_clone().context("cloning the stream for the reader")?;
    let mut tx_sess = Session::new(stream);
    // Greet *before* building local problems: the server's accept loop must
    // learn who we are while dataset/oracle construction is still running.
    tx_sess.send_control(FrameKind::Hello, w).context("sending the Hello greeting")?;
    // Local problems are built here, on the owning thread, and never leave.
    let mut table: Vec<(usize, Box<dyn ClientStep>, Rng, Box<dyn LocalProblem>)> =
        part.into_iter()
            .map(|(i, c, r)| {
                let local = factory(i);
                (i, c, r, local)
            })
            .collect();
    let (tx, rx) = mpsc::channel::<(FrameHeader, FramePayload)>();
    std::thread::scope(|s| -> Result<()> {
        // The reader: eagerly drain the socket so the server's downlink
        // writes never block on this worker's compute (see module docs).
        s.spawn(move || {
            let mut rx_sess = Session::new(reader_stream);
            loop {
                match rx_sess.recv() {
                    Ok((hdr, payload)) => {
                        let bye = matches!(payload, FramePayload::Control(FrameKind::Bye));
                        if tx.send((hdr, payload)).is_err() || bye {
                            break;
                        }
                    }
                    // EOF / reset: the server is gone; dropping `tx` ends
                    // the compute loop below.
                    Err(_) => break,
                }
            }
        });
        let result = serve(&mut table, &rx, &mut tx_sess, w, obs);
        // Whatever ended the serve loop, tear the socket down so the reader
        // thread's blocking recv unblocks and the scope can join it.
        let _ = tx_sess.stream_ref().shutdown(std::net::Shutdown::Both);
        result
    })
}

/// The worker's compute loop: decoded downlinks in, framed uplinks (or
/// Error frames) out, until `Bye` or the connection drops.
fn serve(
    table: &mut [(usize, Box<dyn ClientStep>, Rng, Box<dyn LocalProblem>)],
    rx: &mpsc::Receiver<(FrameHeader, FramePayload)>,
    tx_sess: &mut Session<TcpStream>,
    w: usize,
    obs: Obs<'_>,
) -> Result<()> {
    while let Ok((hdr, payload)) = rx.recv() {
        let down = match payload {
            FramePayload::Packet(p) => p,
            FramePayload::Control(FrameKind::Bye) => break,
            _ => bail!("unexpected {:?} frame from the server", hdr.kind),
        };
        let (round, exchange) = (hdr.round as usize, hdr.exchange as usize);
        let client = hdr.client as usize;
        let reply = match table.iter_mut().find(|(i, ..)| *i == client) {
            None => Err(anyhow::anyhow!("client {client} is not owned by worker {w}")),
            Some((_, step, rng, local)) => {
                let ctx = Ctx::client(round, exchange, client);
                let _span = obs.span("compute", Lane::Client(client), ctx);
                // A panicking client must still produce a reply (an
                // Error frame), or the server would wait forever.
                match catch_unwind(AssertUnwindSafe(|| {
                    step.compute(local.as_ref(), round, exchange, &down, rng)
                })) {
                    Ok(res) => res,
                    Err(payload) => Err(anyhow::anyhow!(
                        "client {client} panicked: {}",
                        panic_message(payload)
                    )),
                }
            }
        };
        let sent = match reply {
            Ok(up) => tx_sess.send_packet(&hdr, &up),
            Err(e) => tx_sess.send_error(&hdr, &format!("{e:#}")),
        };
        if sent.is_err() {
            break; // server gone mid-reply — shut down quietly
        }
    }
    Ok(())
}

impl Transport for Tcp {
    fn exchange(
        &mut self,
        round: usize,
        exchange: usize,
        sends: Vec<(usize, Downlink)>,
    ) -> Result<Vec<(usize, Uplink)>> {
        // Write every downlink first (the workers' reader threads drain
        // them), then read the replies back in the same per-connection
        // order they were written.
        for (client, down) in &sends {
            self.conns[client % self.workers]
                .send_packet(&FrameHeader::packet(round, exchange, *client), down)
                .with_context(|| format!("sending to client {client}, round {round}"))?;
        }
        let mut replies = Vec::with_capacity(sends.len());
        for (client, _) in &sends {
            let (hdr, payload) = self.conns[client % self.workers]
                .recv()
                .with_context(|| format!("awaiting client {client}, round {round}"))?;
            let up = match payload {
                FramePayload::Packet(p) => p,
                FramePayload::Error(msg) => bail!("client {client}, round {round}: {msg}"),
                FramePayload::Control(k) => {
                    bail!("unexpected {k:?} frame from client {client}, round {round}")
                }
            };
            let want = FrameHeader::packet(round, exchange, *client);
            if hdr != want {
                bail!(
                    "out-of-sequence frame from client {client}: \
                     got round {}/exchange {}/client {}, expected round {round}/exchange {exchange}",
                    hdr.round,
                    hdr.exchange,
                    hdr.client
                );
            }
            replies.push((*client, up));
        }
        // Restore the deterministic (lockstep) order before the server
        // absorbs, mirroring the Threaded backend.
        replies.sort_by_key(|(i, _)| *i);
        Ok(replies)
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        // Orderly shutdown: tell every worker to stop reading. Errors are
        // moot — a dead connection shuts the worker down just as well.
        for sess in &mut self.conns {
            let _ = sess.send_control(FrameKind::Bye, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::BitCost;
    use crate::problem::QuadraticProblem;
    use crate::transport::{client_rngs, Packet};

    /// Echo client, as in the threaded backend's tests: replies with its id
    /// and the downlink's scalar doubled; `boom` panics on round ≥ 1.
    /// Unlike the in-process backends, every packet here crosses the codec,
    /// so the test must speak registered kinds ("x" down, "avg" up).
    struct Echo {
        id: usize,
        boom: bool,
    }

    impl ClientStep for Echo {
        fn compute(
            &mut self,
            _local: &dyn LocalProblem,
            round: usize,
            _exchange: usize,
            down: &Downlink,
            _rng: &mut Rng,
        ) -> Result<Uplink> {
            if self.boom && round >= 1 {
                panic!("client {} exploded", self.id);
            }
            let x = down.scalars("x")?[0];
            let mut up = Packet::empty();
            up.push_scalars("avg", vec![self.id as f64, 2.0 * x], BitCost::floats(2));
            Ok(up)
        }
    }

    fn factory() -> impl Fn(usize) -> Box<dyn LocalProblem> + Sync {
        |_i| {
            Box::new(QuadraticProblem::new(crate::linalg::Mat::diag(&[1.0]), vec![0.0]))
                as Box<dyn LocalProblem>
        }
    }

    fn sends(n: usize, x: f64) -> Vec<(usize, Downlink)> {
        (0..n)
            .map(|i| {
                let mut d = Packet::empty();
                d.push_scalars("x", vec![x + i as f64], BitCost::zero());
                (i, d)
            })
            .collect()
    }

    #[test]
    fn replies_cross_real_sockets_in_client_order() {
        let n = 7;
        let clients: Vec<Box<dyn ClientStep>> =
            (0..n).map(|id| Box::new(Echo { id, boom: false }) as Box<dyn ClientStep>).collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t =
                Tcp::spawn(scope, 3, clients, client_rngs(1, n), &f, Obs::noop()).unwrap();
            for round in 0..4 {
                let replies = t.exchange(round, 0, sends(n, 10.0 * round as f64)).unwrap();
                assert_eq!(replies.len(), n);
                for (expect, (i, up)) in replies.iter().enumerate() {
                    assert_eq!(*i, expect);
                    let echo = up.scalars("avg").unwrap();
                    assert_eq!(echo[0] as usize, expect);
                    assert_eq!(echo[1], 2.0 * (10.0 * round as f64 + expect as f64));
                }
            }
        });
    }

    #[test]
    fn panicking_client_surfaces_as_an_error_frame() {
        let n = 4;
        let clients: Vec<Box<dyn ClientStep>> = (0..n)
            .map(|id| Box::new(Echo { id, boom: id == 2 }) as Box<dyn ClientStep>)
            .collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t =
                Tcp::spawn(scope, 2, clients, client_rngs(1, n), &f, Obs::noop()).unwrap();
            assert_eq!(t.exchange(0, 0, sends(n, 0.0)).unwrap().len(), n);
            let err = t.exchange(1, 0, sends(n, 0.0)).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("client 2") && msg.contains("exploded"), "{msg}");
        });
    }

    #[test]
    fn more_workers_than_clients_is_fine() {
        let n = 2;
        let clients: Vec<Box<dyn ClientStep>> =
            (0..n).map(|id| Box::new(Echo { id, boom: false }) as Box<dyn ClientStep>).collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t =
                Tcp::spawn(scope, 16, clients, client_rngs(1, n), &f, Obs::noop()).unwrap();
            let replies = t.exchange(0, 0, sends(n, 1.0)).unwrap();
            assert_eq!(replies.len(), n);
        });
    }
}
