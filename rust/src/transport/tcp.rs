//! The real-socket backend: every exchange crosses TCP as bytes.
//!
//! Layout: one listener per round loop, one connection per worker (client
//! `i` is pinned to worker `i % workers`, exactly like [`super::Threaded`]).
//! Downlinks are encoded by [`super::codec`], framed by
//! [`super::session::Session`], written to the worker's socket, decoded on
//! the worker, computed, and the uplink comes back the same way — so the
//! server-side [`crate::coordinator::CommTally`] is derived from packets
//! that were *actually serialized and decoded*, and the codec's exact f64
//! round-trip is what keeps the tally (and the whole
//! [`crate::metrics::History`]) bit-identical to the in-process backends
//! (`tests/transport_equivalence.rs`).
//!
//! Two ways to register the workers, one serving path:
//!
//! * [`Tcp::spawn`] — in-process federation: scoped worker *threads* connect
//!   back over loopback and self-identify with a `Hello` greeting
//!   (`--transport tcp:<k>`).
//! * [`TcpServer`] — multi-process federation: standalone `repro worker`
//!   processes dial in, send `Join`, and receive an `Assign` frame carrying
//!   the run fingerprint, wire-rendered config, and data recipe so they can
//!   rebuild their clients locally (`--listen <host:port>`, see
//!   `crate::coordinator::remote` and docs/WIRE.md).
//!
//! Both produce the same [`Tcp`] transport; the worker side of the
//! connection is [`super::worker::serve_connection`] in both cases.
//!
//! Deadlock freedom: the server writes every downlink of an exchange before
//! reading any uplink, so a worker must never be the reason a downlink
//! write blocks. Each worker therefore runs a dedicated reader thread that
//! eagerly drains its socket into an in-process channel; compute happens
//! behind that buffer. Uplink writes can block at worst until the server
//! finishes its (bounded) downlink writes and starts reading.
//!
//! Handshake liveness: the accept loop never blocks on any single
//! connection — greetings complete on short-lived per-connection threads
//! whose reads are bounded by the configurable handshake timeout
//! (`RunConfig::handshake_timeout_ms`), so one stalled or dead worker can
//! neither starve the other accepts nor hang the run past the deadline.
//!
//! Sequencing: every frame carries `(round, exchange, client)` and the
//! server verifies them against its expectation on receipt — a misrouted or
//! stale frame is an immediate error, never silent state corruption.
//! Replies are read per-connection in the order the downlinks were written
//! (workers are single-threaded and FIFO), then sorted by client index, so
//! the absorb order is identical to [`super::Lockstep`].
//!
//! Tracing: each client's work still emits its `compute` span (on the
//! worker, client lane) and the round loop's `bits` events are emitted by
//! the coordinator from the same decoded packets the server absorbs, so a
//! traced TCP run validates like any other (`python/analysis/load_trace.py`).

use super::codec::{Assignment, FrameHeader, FrameKind};
use super::session::{FramePayload, Session};
use super::worker::{serve_connection, ClientTable};
use super::{ClientStep, Downlink, ProblemFactory, Transport, Uplink};
use crate::obs::Obs;
use crate::rng::Rng;
use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::thread::Scope;
use std::time::Duration;

/// One client pinned to a worker: index, state, private RNG stream.
type ClientSlot = (usize, Box<dyn ClientStep>, Rng);

/// The server half: one framed connection per worker. Created by
/// [`Tcp::spawn`] (thread workers) or [`TcpServer::accept_remote`] (worker
/// processes); dropping it sends `Bye` on every connection so the workers
/// shut down (and, under [`Tcp::spawn`], the scoped threads join).
pub struct Tcp {
    /// Connection `w` serves the clients of residue class `w`.
    conns: Vec<Session<TcpStream>>,
    workers: usize,
}

impl Tcp {
    /// Bind a loopback listener, spawn `workers` scoped client threads that
    /// connect back to it, and complete the `Hello` handshake with each
    /// (bounded by `timeout`). Worker `w` owns the client states (and
    /// factory-built local problems) of residue class `w`, exactly like
    /// [`super::Threaded`].
    pub fn spawn<'scope, 'env: 'scope>(
        scope: &'scope Scope<'scope, 'env>,
        workers: usize,
        clients: Vec<Box<dyn ClientStep>>,
        rngs: Vec<Rng>,
        factory: ProblemFactory<'env>,
        obs: Obs<'env>,
        timeout: Duration,
    ) -> Result<Tcp> {
        assert_eq!(clients.len(), rngs.len(), "rngs/clients length mismatch");
        let workers = workers.clamp(1, clients.len().max(1));
        let listener =
            TcpListener::bind(("127.0.0.1", 0)).context("binding the loopback listener")?;
        let addr = listener.local_addr().context("reading the listener address")?;
        let mut parts: Vec<Vec<ClientSlot>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, (c, r)) in clients.into_iter().zip(rngs).enumerate() {
            parts[i % workers].push((i, c, r));
        }
        for (w, part) in parts.into_iter().enumerate() {
            scope.spawn(move || {
                if let Err(e) = worker_main(addr, w, part, factory, obs) {
                    // The server sees the broken/missing connection and
                    // fails the exchange; this is diagnostics, not control.
                    eprintln!("tcp transport worker {w}: {e:#}");
                }
            });
        }
        let conns = accept_workers(&listener, workers, timeout, &GreetMode::Hello)?;
        Ok(Tcp { conns, workers })
    }
}

/// A listening round-loop endpoint for standalone worker processes
/// (`repro worker --connect`). Split into bind/accept phases so the caller
/// can announce the bound address (port 0 resolves to a free port) *before*
/// blocking in the accept handshake.
pub struct TcpServer {
    listener: TcpListener,
    workers: usize,
    timeout: Duration,
}

impl TcpServer {
    /// Bind `addr` (`host:port`; port 0 picks a free one) to register
    /// `workers` remote workers, each handshake bounded by `timeout`.
    pub fn bind(addr: &str, workers: usize, timeout: Duration) -> Result<TcpServer> {
        anyhow::ensure!(workers >= 1, "need at least one worker");
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding the round-loop listener on {addr}"))?;
        Ok(TcpServer { listener, workers, timeout })
    }

    /// The bound address (resolves a port-0 bind to the actual port).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("reading the listener address")
    }

    /// Accept and handshake all registered remote workers (`Join` →
    /// `Assign` → `Hello`, docs/WIRE.md) and return the connected
    /// transport. Worker indices are handed out in arrival order.
    pub fn accept_remote(&self, assignment: &Assignment) -> Result<Tcp> {
        let conns = accept_workers(
            &self.listener,
            self.workers,
            self.timeout,
            &GreetMode::Assign(assignment.clone()),
        )?;
        Ok(Tcp { conns, workers: self.workers })
    }
}

/// Which greeting protocol the accept loop runs per connection.
#[derive(Clone)]
enum GreetMode {
    /// In-process thread workers self-identify: a single `Hello(w)`.
    Hello,
    /// Remote worker processes: `Join` in, `Assign` out (index = arrival
    /// order), then `Hello(w)` once the worker has rebuilt its data — or an
    /// `Error` frame if it rejects the assignment.
    Assign(Assignment),
}

/// The greeting exchange for one accepted connection. Runs on its own
/// short-lived thread so a stalled peer cannot starve the accept loop; each
/// read is bounded by the handshake read timeout already set on the stream.
fn greet_worker(
    stream: TcpStream,
    index: usize,
    mode: GreetMode,
) -> Result<(usize, Session<TcpStream>)> {
    let mut sess = Session::new(stream);
    if let GreetMode::Assign(assignment) = &mode {
        let (hdr, payload) = sess.recv().context("reading a worker's Join request")?;
        if !matches!(payload, FramePayload::Control(FrameKind::Join)) {
            bail!("expected a Join request, got a {:?} frame", hdr.kind);
        }
        sess.send_assign(index, assignment).context("sending the run assignment")?;
    }
    let (hdr, payload) = sess.recv().context("reading a worker greeting")?;
    match payload {
        FramePayload::Control(FrameKind::Hello) => {}
        FramePayload::Error(msg) => bail!("worker {index} rejected its assignment: {msg}"),
        _ => bail!("expected a Hello greeting, got a {:?} frame", hdr.kind),
    }
    let w = hdr.client as usize;
    if matches!(mode, GreetMode::Assign(_)) && w != index {
        bail!("worker greeted as {w} but was assigned index {index}");
    }
    Ok((w, sess))
}

/// Accept until every worker has connected and completed its greeting, or
/// the deadline passes. The accept loop itself never blocks: connections
/// are accepted nonblockingly and their greetings complete on per-
/// connection threads (each read bounded by `timeout`), so a dead worker
/// surfaces as the timeout error and a stalled one cannot starve the rest.
fn accept_workers(
    listener: &TcpListener,
    workers: usize,
    timeout: Duration,
    mode: &GreetMode,
) -> Result<Vec<Session<TcpStream>>> {
    listener.set_nonblocking(true).context("making the listener nonblocking")?;
    // audit:allow(determinism-clock): wall-clock here only bounds the connection handshake; no run result depends on it.
    let deadline = std::time::Instant::now() + timeout;
    let (done_tx, done_rx) = mpsc::channel::<Result<(usize, Session<TcpStream>)>>();
    let mut accepted = 0usize;
    let mut conns: Vec<Option<Session<TcpStream>>> = (0..workers).map(|_| None).collect();
    let mut connected = 0usize;
    while connected < workers {
        // Drain everything the listener has ready before waiting on
        // greetings — acceptance must never wait behind a slow peer.
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).context("restoring blocking mode")?;
                    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                    // Bound every greeting read; the round loop restores
                    // fully blocking reads below.
                    stream
                        .set_read_timeout(Some(timeout))
                        .context("setting the handshake read timeout")?;
                    let index = accepted;
                    accepted += 1;
                    let tx = done_tx.clone();
                    let mode = mode.clone();
                    std::thread::spawn(move || {
                        let _ = tx.send(greet_worker(stream, index, mode));
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("accepting a worker connection"),
            }
        }
        // Wait briefly for a completed greeting (this doubles as the accept
        // loop's idle sleep), then go accept again.
        match done_rx.recv_timeout(Duration::from_millis(2)) {
            Ok(Ok((w, sess))) => {
                if w >= workers || conns[w].is_some() {
                    bail!("invalid or duplicate worker greeting (worker {w} of {workers})");
                }
                conns[w] = Some(sess);
                connected += 1;
            }
            Ok(Err(e)) => return Err(e).context("completing a worker handshake"),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Unreachable — this function owns a live `done_tx` clone.
                bail!("worker greeting channel closed unexpectedly");
            }
        }
        // audit:allow(determinism-clock): wall-clock here only bounds the connection handshake; no run result depends on it.
        if connected < workers && std::time::Instant::now() >= deadline {
            bail!("timed out waiting for {} of {workers} workers", workers - connected);
        }
    }
    let mut out = Vec::with_capacity(workers);
    for sess in conns.into_iter().flatten() {
        sess.stream_ref()
            .set_read_timeout(None)
            .context("clearing the handshake read timeout")?;
        out.push(sess);
    }
    Ok(out)
}

/// One in-process worker thread: connect, greet, build local problems, then
/// serve decoded downlinks until `Bye` (or the connection drops).
fn worker_main(
    addr: std::net::SocketAddr,
    w: usize,
    part: Vec<ClientSlot>,
    factory: ProblemFactory<'_>,
    obs: Obs<'_>,
) -> Result<()> {
    let stream = TcpStream::connect(addr).context("connecting to the round loop")?;
    stream.set_nodelay(true).context("setting TCP_NODELAY")?;
    let mut sess = Session::new(stream);
    // Greet *before* building local problems: the server's accept loop must
    // learn who we are while dataset/oracle construction is still running.
    sess.send_control(FrameKind::Hello, w).context("sending the Hello greeting")?;
    // Local problems are built here, on the owning thread, and never leave.
    let table: ClientTable = part
        .into_iter()
        .map(|(i, c, r)| {
            let local = factory(i);
            (i, c, r, local)
        })
        .collect();
    serve_connection(sess.into_inner(), table, w, obs)
}

impl Transport for Tcp {
    fn exchange(
        &mut self,
        round: usize,
        exchange: usize,
        sends: Vec<(usize, Downlink)>,
    ) -> Result<Vec<(usize, Uplink)>> {
        // Write every downlink first (the workers' reader threads drain
        // them), then read the replies back in the same per-connection
        // order they were written.
        for (client, down) in &sends {
            self.conns[client % self.workers]
                .send_packet(&FrameHeader::packet(round, exchange, *client), down)
                .with_context(|| format!("sending to client {client}, round {round}"))?;
        }
        let mut replies = Vec::with_capacity(sends.len());
        for (client, _) in &sends {
            let (hdr, payload) = self.conns[client % self.workers]
                .recv()
                .with_context(|| format!("awaiting client {client}, round {round}"))?;
            let up = match payload {
                FramePayload::Packet(p) => p,
                FramePayload::Error(msg) => bail!("client {client}, round {round}: {msg}"),
                FramePayload::Assign(_) | FramePayload::Control(_) => {
                    bail!("unexpected {:?} frame from client {client}, round {round}", hdr.kind)
                }
            };
            let want = FrameHeader::packet(round, exchange, *client);
            if hdr != want {
                bail!(
                    "out-of-sequence frame from client {client}: \
                     got round {}/exchange {}/client {}, expected round {round}/exchange {exchange}",
                    hdr.round,
                    hdr.exchange,
                    hdr.client
                );
            }
            replies.push((*client, up));
        }
        // Restore the deterministic (lockstep) order before the server
        // absorbs, mirroring the Threaded backend.
        replies.sort_by_key(|(i, _)| *i);
        Ok(replies)
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        // Orderly shutdown: tell every worker to stop reading. Errors are
        // moot — a dead connection shuts the worker down just as well.
        for sess in &mut self.conns {
            let _ = sess.send_control(FrameKind::Bye, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::BitCost;
    use crate::problem::{LocalProblem, QuadraticProblem};
    use crate::transport::{client_rngs, Packet};

    const TEST_TIMEOUT: Duration = Duration::from_secs(30);

    /// Echo client, as in the threaded backend's tests: replies with its id
    /// and the downlink's scalar doubled; `boom` panics on round ≥ 1.
    /// Unlike the in-process backends, every packet here crosses the codec,
    /// so the test must speak registered kinds ("x" down, "avg" up).
    struct Echo {
        id: usize,
        boom: bool,
    }

    impl ClientStep for Echo {
        fn compute(
            &mut self,
            _local: &dyn LocalProblem,
            round: usize,
            _exchange: usize,
            down: &Downlink,
            _rng: &mut Rng,
        ) -> Result<Uplink> {
            if self.boom && round >= 1 {
                panic!("client {} exploded", self.id);
            }
            let x = down.scalars("x")?[0];
            let mut up = Packet::empty();
            up.push_scalars("avg", vec![self.id as f64, 2.0 * x], BitCost::floats(2));
            Ok(up)
        }
    }

    fn factory() -> impl Fn(usize) -> Box<dyn LocalProblem> + Sync {
        |_i| {
            Box::new(QuadraticProblem::new(crate::linalg::Mat::diag(&[1.0]), vec![0.0]))
                as Box<dyn LocalProblem>
        }
    }

    fn sends(n: usize, x: f64) -> Vec<(usize, Downlink)> {
        (0..n)
            .map(|i| {
                let mut d = Packet::empty();
                d.push_scalars("x", vec![x + i as f64], BitCost::zero());
                (i, d)
            })
            .collect()
    }

    #[test]
    fn replies_cross_real_sockets_in_client_order() {
        let n = 7;
        let clients: Vec<Box<dyn ClientStep>> =
            (0..n).map(|id| Box::new(Echo { id, boom: false }) as Box<dyn ClientStep>).collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t =
                Tcp::spawn(scope, 3, clients, client_rngs(1, n), &f, Obs::noop(), TEST_TIMEOUT)
                    .unwrap();
            for round in 0..4 {
                let replies = t.exchange(round, 0, sends(n, 10.0 * round as f64)).unwrap();
                assert_eq!(replies.len(), n);
                for (expect, (i, up)) in replies.iter().enumerate() {
                    assert_eq!(*i, expect);
                    let echo = up.scalars("avg").unwrap();
                    assert_eq!(echo[0] as usize, expect);
                    assert_eq!(echo[1], 2.0 * (10.0 * round as f64 + expect as f64));
                }
            }
        });
    }

    #[test]
    fn panicking_client_surfaces_as_an_error_frame() {
        let n = 4;
        let clients: Vec<Box<dyn ClientStep>> = (0..n)
            .map(|id| Box::new(Echo { id, boom: id == 2 }) as Box<dyn ClientStep>)
            .collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t =
                Tcp::spawn(scope, 2, clients, client_rngs(1, n), &f, Obs::noop(), TEST_TIMEOUT)
                    .unwrap();
            assert_eq!(t.exchange(0, 0, sends(n, 0.0)).unwrap().len(), n);
            let err = t.exchange(1, 0, sends(n, 0.0)).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("client 2") && msg.contains("exploded"), "{msg}");
        });
    }

    #[test]
    fn more_workers_than_clients_is_fine() {
        let n = 2;
        let clients: Vec<Box<dyn ClientStep>> =
            (0..n).map(|id| Box::new(Echo { id, boom: false }) as Box<dyn ClientStep>).collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t =
                Tcp::spawn(scope, 16, clients, client_rngs(1, n), &f, Obs::noop(), TEST_TIMEOUT)
                    .unwrap();
            let replies = t.exchange(0, 0, sends(n, 1.0)).unwrap();
            assert_eq!(replies.len(), n);
        });
    }

    #[test]
    fn dead_worker_times_out_cleanly() {
        // Nobody ever connects: the accept phase must surface the timeout
        // error within the (sub-second) deadline, not hang.
        let srv = TcpServer::bind("127.0.0.1:0", 2, Duration::from_millis(300)).unwrap();
        let assignment = Assignment {
            fingerprint: 1,
            workers: 2,
            clients: 2,
            config: String::new(),
            recipe: String::new(),
        };
        let err = srv.accept_remote(&assignment).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out waiting for 2 of 2 workers"), "{msg}");
    }

    #[test]
    fn stalled_greeting_does_not_starve_other_workers() {
        // One connection opens but never greets; the workers that do greet
        // must still be accepted (the old code read greetings blockingly
        // inside the accept loop, so the stalled peer starved everyone).
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let stall = TcpStream::connect(addr).unwrap();
        let greeters: Vec<_> = (0..2)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut s = Session::new(TcpStream::connect(addr).unwrap());
                    s.send_control(FrameKind::Hello, w).unwrap();
                    s // keep the connection open until accept completes
                })
            })
            .collect();
        let conns =
            accept_workers(&listener, 2, Duration::from_secs(10), &GreetMode::Hello).unwrap();
        assert_eq!(conns.len(), 2);
        drop(stall);
        for g in greeters {
            g.join().unwrap();
        }
    }
}
