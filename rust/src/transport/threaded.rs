//! The concurrent in-round backend: a scoped worker pool executes each
//! addressed client's per-exchange work (Hessian evaluation, basis
//! projection, compression — the dominant cost of a BL/FedNL round) in
//! parallel.
//!
//! Determinism: client `i` is pinned to worker `i % workers` for the whole
//! run, owns its private RNG stream, and uplinks are sorted by client index
//! before they are handed back — so the server observes exactly the
//! [`super::Lockstep`] order no matter how the OS schedules the workers.
//!
//! Each worker builds its *own* local problems through the
//! [`super::ProblemFactory`] on its own thread, because
//! [`crate::problem::LocalProblem`] is deliberately non-`Send`.
//!
//! When traced, each job yields two spans on the client's lane: `queue`
//! (enqueue on the main thread → dequeue on the worker; cross-thread, so
//! it uses the recorder's shared monotonic epoch) and `compute` (the
//! client work itself) — separating pool contention from real work.

use super::{ClientStep, Downlink, ProblemFactory, Transport, Uplink};
use crate::obs::{Ctx, Lane, Obs};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::thread::Scope;

/// One client pinned to a worker: index, state, private RNG stream.
type ClientSlot = (usize, Box<dyn ClientStep>, Rng);
/// A slot plus the worker-built local problem it talks to.
type WorkerSlot = (usize, Box<dyn ClientStep>, Rng, Box<dyn LocalProblem>);

/// One unit of client work.
struct Job {
    round: usize,
    exchange: usize,
    client: usize,
    down: Downlink,
    /// Enqueue timestamp (recorder epoch µs; 0 when untraced) — the start
    /// of the job's `queue` span.
    sent_us: f64,
}

/// Scoped worker-pool transport. Create with [`Threaded::spawn`] inside a
/// [`std::thread::scope`]; dropping it shuts the workers down (the scope
/// then joins them).
pub struct Threaded<'a> {
    /// Per-worker job queues; client `i` is routed to `i % workers`.
    to_workers: Vec<mpsc::Sender<Job>>,
    results: mpsc::Receiver<(usize, Result<Uplink>)>,
    workers: usize,
    obs: Obs<'a>,
}

impl Threaded<'_> {
    /// Spawn `workers` scoped threads, each owning the client states (and
    /// factory-built local problems) of its residual class.
    pub fn spawn<'scope, 'env: 'scope>(
        scope: &'scope Scope<'scope, 'env>,
        workers: usize,
        clients: Vec<Box<dyn ClientStep>>,
        rngs: Vec<Rng>,
        factory: ProblemFactory<'env>,
    ) -> Threaded<'env> {
        Threaded::spawn_obs(scope, workers, clients, rngs, factory, Obs::noop())
    }

    /// [`Threaded::spawn`] with a trace recorder shared by the main thread
    /// (enqueue stamps) and every worker (queue/compute spans).
    pub fn spawn_obs<'scope, 'env: 'scope>(
        scope: &'scope Scope<'scope, 'env>,
        workers: usize,
        clients: Vec<Box<dyn ClientStep>>,
        rngs: Vec<Rng>,
        factory: ProblemFactory<'env>,
        obs: Obs<'env>,
    ) -> Threaded<'env> {
        assert_eq!(clients.len(), rngs.len(), "rngs/clients length mismatch");
        let workers = workers.clamp(1, clients.len().max(1));
        let mut parts: Vec<Vec<ClientSlot>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, (c, r)) in clients.into_iter().zip(rngs).enumerate() {
            parts[i % workers].push((i, c, r));
        }
        let (res_tx, res_rx) = mpsc::channel::<(usize, Result<Uplink>)>();
        let mut to_workers = Vec::with_capacity(workers);
        for part in parts {
            let (job_tx, job_rx) = mpsc::channel::<Job>();
            to_workers.push(job_tx);
            let res_tx = res_tx.clone();
            scope.spawn(move || worker_loop(part, job_rx, res_tx, factory, obs));
        }
        Threaded { to_workers, results: res_rx, workers, obs }
    }
}

fn worker_loop(
    part: Vec<ClientSlot>,
    jobs: mpsc::Receiver<Job>,
    results: mpsc::Sender<(usize, Result<Uplink>)>,
    factory: ProblemFactory<'_>,
    obs: Obs<'_>,
) {
    // Local problems are built here, on the owning thread, and never leave.
    let mut table: Vec<WorkerSlot> = part
        .into_iter()
        .map(|(i, c, r)| {
            let local = factory(i);
            (i, c, r, local)
        })
        .collect();
    while let Ok(job) = jobs.recv() {
        let ctx = Ctx::client(job.round, job.exchange, job.client);
        if obs.enabled() {
            // Queue wait: enqueue stamp (main thread) → now (this worker).
            obs.span_at("queue", Lane::Client(job.client), ctx, job.sent_us, obs.now_us());
        }
        let reply = match table.iter_mut().find(|(i, ..)| *i == job.client) {
            None => Err(anyhow!("client {} is not owned by this worker", job.client)),
            Some((_, step, rng, local)) => {
                let _span = obs.span("compute", Lane::Client(job.client), ctx);
                // A panicking client must still produce a reply, or the
                // main thread would wait forever for this exchange.
                match catch_unwind(AssertUnwindSafe(|| {
                    step.compute(local.as_ref(), job.round, job.exchange, &job.down, rng)
                })) {
                    Ok(res) => res,
                    Err(payload) => Err(anyhow!(
                        "client {} panicked: {}",
                        job.client,
                        panic_message(payload)
                    )),
                }
            }
        };
        if results.send((job.client, reply)).is_err() {
            break; // transport dropped — shut down
        }
    }
}

pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string payload>".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::BitCost;
    use crate::problem::QuadraticProblem;
    use crate::transport::{client_rngs, Packet};

    /// Echo client: replies with its id and the downlink's scalar doubled.
    /// `boom` panics on round ≥ 1 — driving the worker's catch_unwind path.
    struct Echo {
        id: usize,
        boom: bool,
    }

    impl ClientStep for Echo {
        fn compute(
            &mut self,
            _local: &dyn LocalProblem,
            round: usize,
            _exchange: usize,
            down: &Downlink,
            _rng: &mut Rng,
        ) -> Result<Uplink> {
            if self.boom && round >= 1 {
                panic!("client {} exploded", self.id);
            }
            let x = down.scalars("x")?[0];
            let mut up = Packet::empty();
            up.push_scalars("echo", vec![self.id as f64, 2.0 * x], BitCost::floats(2));
            Ok(up)
        }
    }

    fn factory() -> impl Fn(usize) -> Box<dyn LocalProblem> + Sync {
        |_i| {
            Box::new(QuadraticProblem::new(crate::linalg::Mat::diag(&[1.0]), vec![0.0]))
                as Box<dyn LocalProblem>
        }
    }

    fn sends(n: usize, x: f64) -> Vec<(usize, Downlink)> {
        (0..n)
            .map(|i| {
                let mut d = Packet::empty();
                d.push_scalars("x", vec![x + i as f64], BitCost::zero());
                (i, d)
            })
            .collect()
    }

    #[test]
    fn replies_come_back_in_client_order() {
        let n = 7;
        let clients: Vec<Box<dyn ClientStep>> =
            (0..n).map(|id| Box::new(Echo { id, boom: false }) as Box<dyn ClientStep>).collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t = Threaded::spawn(scope, 3, clients, client_rngs(1, n), &f);
            for round in 0..4 {
                let replies = t.exchange(round, 0, sends(n, 10.0 * round as f64)).unwrap();
                assert_eq!(replies.len(), n);
                for (expect, (i, up)) in replies.iter().enumerate() {
                    // Sorted ascending regardless of worker scheduling, and
                    // each reply really came from the addressed client.
                    assert_eq!(*i, expect);
                    let echo = up.scalars("echo").unwrap();
                    assert_eq!(echo[0] as usize, expect);
                    assert_eq!(echo[1], 2.0 * (10.0 * round as f64 + expect as f64));
                }
            }
        });
    }

    #[test]
    fn panicking_client_yields_error_not_deadlock() {
        // The worker must reply even when compute panics, or the exchange
        // would wait forever; the error surfaces cleanly on the main thread.
        let n = 4;
        let clients: Vec<Box<dyn ClientStep>> = (0..n)
            .map(|id| Box::new(Echo { id, boom: id == 2 }) as Box<dyn ClientStep>)
            .collect();
        let f = factory();
        std::thread::scope(|scope| {
            let mut t = Threaded::spawn(scope, 2, clients, client_rngs(1, n), &f);
            // Round 0 is fine…
            assert_eq!(t.exchange(0, 0, sends(n, 0.0)).unwrap().len(), n);
            // …round 1 panics in client 2's worker: clean Err, no hang, and
            // the message names the culprit.
            let err = t.exchange(1, 0, sends(n, 0.0)).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("client 2") && msg.contains("exploded"), "{msg}");
        });
    }

    #[test]
    fn traced_pool_emits_queue_and_compute_spans() {
        use crate::obs::{JsonlRecorder, Recorder};
        let n = 4;
        let clients: Vec<Box<dyn ClientStep>> =
            (0..n).map(|id| Box::new(Echo { id, boom: false }) as Box<dyn ClientStep>).collect();
        let f = factory();
        let path = std::env::temp_dir()
            .join(format!("bl_threaded_trace_{}", std::process::id()));
        let rec = JsonlRecorder::create(&path).unwrap();
        std::thread::scope(|scope| {
            let mut t =
                Threaded::spawn_obs(scope, 2, clients, client_rngs(1, n), &f, Obs::new(&rec));
            t.exchange(0, 0, sends(n, 1.0)).unwrap();
        });
        rec.flush().unwrap();
        let load = crate::sweep::load_jsonl(&path).unwrap();
        let names: Vec<&str> = load
            .rows
            .iter()
            .filter_map(|r| r.get("name").and_then(crate::sweep::Json::as_str))
            .collect();
        // One queue + one compute span per client job.
        assert_eq!(names.iter().filter(|s| **s == "queue").count(), n);
        assert_eq!(names.iter().filter(|s| **s == "compute").count(), n);
        for row in &load.rows {
            assert!(row.get("client").is_some(), "{row:?}");
            assert!(row.get("dur_us").unwrap().as_f64().unwrap() >= 0.0);
        }
        std::fs::remove_file(&path).unwrap();
    }
}

impl Transport for Threaded<'_> {
    fn exchange(
        &mut self,
        round: usize,
        exchange: usize,
        sends: Vec<(usize, Downlink)>,
    ) -> Result<Vec<(usize, Uplink)>> {
        let expected = sends.len();
        let sent_us = if self.obs.enabled() { self.obs.now_us() } else { 0.0 };
        for (client, down) in sends {
            let w = client % self.workers;
            self.to_workers[w]
                .send(Job { round, exchange, client, down, sent_us })
                .map_err(|_| anyhow!("transport worker {w} shut down"))?;
        }
        let mut replies: Vec<(usize, Result<Uplink>)> = Vec::with_capacity(expected);
        for _ in 0..expected {
            let r = self
                .results
                .recv()
                .map_err(|_| anyhow!("transport workers disconnected mid-exchange"))?;
            replies.push(r);
        }
        // Restore the deterministic (lockstep) order before the server
        // absorbs; errors surface lowest-client-first for determinism too.
        replies.sort_by_key(|(i, _)| *i);
        let mut out = Vec::with_capacity(expected);
        for (i, r) in replies {
            out.push((i, r.with_context(|| format!("client {i}, round {round}"))?));
        }
        Ok(out)
    }
}
