//! The worker half of the socket backend, shared by the in-process thread
//! workers ([`super::Tcp::spawn`]) and the standalone `repro worker`
//! process (`crate::coordinator::remote`).
//!
//! Once a connection's handshake is done (however it was established —
//! `Hello` for spawned threads, `Join`/`Assign`/`Hello` for remote
//! processes, see docs/WIRE.md), the serving side is identical: a dedicated
//! reader thread eagerly drains the socket into an in-process channel (so
//! the server's downlink writes never block on this worker's compute — the
//! deadlock-freedom argument in [`super::tcp`]'s module docs), while the
//! compute loop decodes downlinks, runs the owned clients, and frames the
//! uplinks (or Error frames) back.

use super::codec::{FrameHeader, FrameKind};
use super::session::{FramePayload, Session};
use super::threaded::panic_message;
use super::ClientStep;
use crate::obs::{Ctx, Lane, Obs};
use crate::problem::LocalProblem;
use crate::rng::Rng;
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;

/// One served client: global index, protocol half, private RNG stream, and
/// the locally-built problem its oracle calls run against. Local problems
/// are built on the owning thread/process and never leave it
/// ([`LocalProblem`] is deliberately non-`Send`).
pub type ClientTable = Vec<(usize, Box<dyn ClientStep>, Rng, Box<dyn LocalProblem>)>;

/// Serve an established (post-handshake) connection until the round loop
/// says `Bye` or the connection drops: spawn the reader thread, run the
/// compute loop, then tear the socket down so the reader unblocks and
/// joins.
pub fn serve_connection(
    stream: TcpStream,
    mut table: ClientTable,
    w: usize,
    obs: Obs<'_>,
) -> Result<()> {
    let reader_stream = stream.try_clone().context("cloning the stream for the reader")?;
    let mut tx_sess = Session::new(stream);
    let (tx, rx) = mpsc::channel::<(FrameHeader, FramePayload)>();
    std::thread::scope(|s| -> Result<()> {
        // The reader: eagerly drain the socket so the server's downlink
        // writes never block on this worker's compute (see module docs).
        s.spawn(move || {
            let mut rx_sess = Session::new(reader_stream);
            loop {
                match rx_sess.recv() {
                    Ok((hdr, payload)) => {
                        let bye = matches!(payload, FramePayload::Control(FrameKind::Bye));
                        if tx.send((hdr, payload)).is_err() || bye {
                            break;
                        }
                    }
                    // EOF / reset: the server is gone; dropping `tx` ends
                    // the compute loop below.
                    Err(_) => break,
                }
            }
        });
        let result = serve(&mut table, &rx, &mut tx_sess, w, obs);
        // Whatever ended the serve loop, tear the socket down so the reader
        // thread's blocking recv unblocks and the scope can join it.
        let _ = tx_sess.stream_ref().shutdown(std::net::Shutdown::Both);
        result
    })
}

/// The worker's compute loop: decoded downlinks in, framed uplinks (or
/// Error frames) out, until `Bye` or the connection drops.
fn serve(
    table: &mut [(usize, Box<dyn ClientStep>, Rng, Box<dyn LocalProblem>)],
    rx: &mpsc::Receiver<(FrameHeader, FramePayload)>,
    tx_sess: &mut Session<TcpStream>,
    w: usize,
    obs: Obs<'_>,
) -> Result<()> {
    while let Ok((hdr, payload)) = rx.recv() {
        let down = match payload {
            FramePayload::Packet(p) => p,
            FramePayload::Control(FrameKind::Bye) => break,
            _ => bail!("unexpected {:?} frame from the server", hdr.kind),
        };
        let (round, exchange) = (hdr.round as usize, hdr.exchange as usize);
        let client = hdr.client as usize;
        let reply = match table.iter_mut().find(|(i, ..)| *i == client) {
            None => Err(anyhow::anyhow!("client {client} is not owned by worker {w}")),
            Some((_, step, rng, local)) => {
                let ctx = Ctx::client(round, exchange, client);
                let _span = obs.span("compute", Lane::Client(client), ctx);
                // A panicking client must still produce a reply (an
                // Error frame), or the server would wait forever.
                match catch_unwind(AssertUnwindSafe(|| {
                    step.compute(local.as_ref(), round, exchange, &down, rng)
                })) {
                    Ok(res) => res,
                    Err(payload) => Err(anyhow::anyhow!(
                        "client {client} panicked: {}",
                        panic_message(payload)
                    )),
                }
            }
        };
        let sent = match reply {
            Ok(up) => tx_sess.send_packet(&hdr, &up),
            Err(e) => tx_sess.send_error(&hdr, &format!("{e:#}")),
        };
        if sent.is_err() {
            break; // server gone mid-reply — shut down quietly
        }
    }
    Ok(())
}
