//! Cross-cutting algorithm invariants, proptest-style: randomized
//! configuration sweeps (our own generator — the crates registry in this
//! environment has no `proptest`) plus edge-case and failure-injection
//! coverage for the whole coordinator stack.

use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, BasisKind, Bl3Option, RunConfig};
use basis_learn::coordinator::run_federated;
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::rng::Rng;

fn fed(n: usize, m: usize, d: usize, r: usize, seed: u64) -> FederatedDataset {
    FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: n,
        m_per_client: m,
        dim: d,
        intrinsic_dim: r,
        noise: 0.0,
        seed,
    })
}

fn default_fed() -> FederatedDataset {
    fed(5, 30, 12, 5, 1234)
}

#[test]
fn every_second_order_method_converges() {
    let f = default_fed();
    for (algo, comp, rounds) in [
        (Algorithm::Newton, CompressorSpec::Identity, 30),
        (Algorithm::Bl1, CompressorSpec::TopK(5), 400),
        (Algorithm::Bl2, CompressorSpec::TopK(5), 600),
        (Algorithm::Bl3, CompressorSpec::TopK(12), 1200),
        (Algorithm::FedNl, CompressorSpec::RankR(1), 400),
        (Algorithm::FedNlPp, CompressorSpec::RankR(1), 600),
        (Algorithm::FedNlBc, CompressorSpec::TopK(72), 600),
        (Algorithm::Nl1, CompressorSpec::RandK(1), 2500),
        (Algorithm::Dingo, CompressorSpec::Identity, 80),
    ] {
        let cfg = RunConfig {
            algorithm: algo,
            hess_comp: comp,
            rounds,
            lambda: 1e-3,
            target_gap: 1e-10,
            ..RunConfig::default()
        };
        let out = run_federated(&f, &cfg).unwrap_or_else(|e| panic!("{algo} failed: {e:#}"));
        assert!(
            out.final_gap() <= 1e-10,
            "{algo}: gap {} after {} rounds",
            out.final_gap(),
            out.history.records.len()
        );
    }
}

#[test]
fn every_first_order_method_converges() {
    let f = default_fed();
    for (algo, grad, model) in [
        (Algorithm::Gd, CompressorSpec::Identity, CompressorSpec::Identity),
        (Algorithm::Diana, CompressorSpec::Dithering(None), CompressorSpec::Identity),
        (Algorithm::Adiana, CompressorSpec::Dithering(None), CompressorSpec::Identity),
        (Algorithm::SLocalGd, CompressorSpec::Identity, CompressorSpec::Identity),
        (Algorithm::Artemis, CompressorSpec::Dithering(None), CompressorSpec::Identity),
        (Algorithm::Dore, CompressorSpec::Dithering(None), CompressorSpec::Dithering(None)),
    ] {
        let cfg = RunConfig {
            algorithm: algo,
            grad_comp: grad,
            model_comp: model,
            rounds: 300_000,
            lambda: 1e-2,
            target_gap: 1e-6,
            ..RunConfig::default()
        };
        let out = run_federated(&f, &cfg).unwrap_or_else(|e| panic!("{algo} failed: {e:#}"));
        assert!(out.final_gap() <= 1e-6, "{algo}: gap {}", out.final_gap());
    }
}

#[test]
fn determinism_across_all_algorithms() {
    let f = default_fed();
    for algo in Algorithm::all() {
        let cfg = RunConfig {
            algorithm: *algo,
            hess_comp: CompressorSpec::TopK(8),
            grad_comp: CompressorSpec::Dithering(Some(4)),
            rounds: 12,
            lambda: 1e-3,
            target_gap: 0.0,
            seed: 777,
            ..RunConfig::default()
        };
        let a = run_federated(&f, &cfg).unwrap();
        let b = run_federated(&f, &cfg).unwrap();
        assert_eq!(a.x_final, b.x_final, "{algo} not deterministic");
        let ra = a.history.records.last().unwrap();
        let rb = b.history.records.last().unwrap();
        assert_eq!(ra.bits_up_per_node, rb.bits_up_per_node, "{algo} bit accounting drifts");
    }
}

#[test]
fn bits_are_monotone_nondecreasing() {
    let f = default_fed();
    for algo in [Algorithm::Bl1, Algorithm::Bl2, Algorithm::Bl3, Algorithm::SLocalGd] {
        let cfg = RunConfig {
            algorithm: algo,
            hess_comp: CompressorSpec::TopK(6),
            rounds: 40,
            target_gap: 0.0,
            ..RunConfig::default()
        };
        let out = run_federated(&f, &cfg).unwrap();
        for w in out.history.records.windows(2) {
            assert!(w[1].bits_up_per_node >= w[0].bits_up_per_node, "{algo}");
            assert!(w[1].bits_down_per_node >= w[0].bits_down_per_node, "{algo}");
        }
    }
}

/// Proptest-style randomized sweep: BL1/BL2/BL3 under randomly drawn
/// compressors, bases, participation and gradient schedules must never
/// diverge, and must make real progress.
#[test]
fn randomized_bl_configurations_never_diverge() {
    let mut gen = Rng::new(0xB17);
    let comp_pool = ["topk:4", "topk:12", "randk:6", "rank:1", "rank:2", "dith:6", "nat",
                     "rrank:1", "nrank:1", "rtopk:6", "ntopk:6"];
    // Model compressors stay contractive (identity/Top-K), like every BL
    // experiment in the paper: unbiased model compression violates
    // Assumption 4.3(ii) (iterates must remain convex combinations of past
    // x's) and is outside the theory's envelope — see the BL1 module docs.
    let model_pool = ["identity", "topk:6"];
    for case in 0..30 {
        let algo = [Algorithm::Bl1, Algorithm::Bl2, Algorithm::Bl3][gen.below(3)];
        let basis = match algo {
            Algorithm::Bl3 => None, // BL3 requires its PSD basis
            _ => Some([BasisKind::Standard, BasisKind::SymTri, BasisKind::Subspace][gen.below(3)]),
        };
        // ≥ 75 total points for d ≤ 13 keeps the logistic problem
        // non-separable, i.e. inside the local basin the paper's theory
        // covers from x⁰ = 0 (near-separable draws push ‖x*‖ ≫ 1 where the
        // lazy-gradient estimator legitimately wanders — demonstrated by
        // bl1_far_from_basin_can_wander below).
        let f = fed(
            3 + gen.below(3),
            25 + gen.below(20),
            6 + gen.below(8),
            3 + gen.below(3),
            1000 + case as u64,
        );
        let cfg = RunConfig {
            algorithm: algo,
            basis,
            hess_comp: CompressorSpec::parse(comp_pool[gen.below(comp_pool.len())]).unwrap(),
            model_comp: CompressorSpec::parse(model_pool[gen.below(model_pool.len())]).unwrap(),
            p: [1.0, 0.5, 0.2][gen.below(3)],
            tau: if gen.bernoulli(0.5) { None } else { Some(1 + gen.below(f.n_clients())) },
            bl3_option: if gen.bernoulli(0.5) { Bl3Option::One } else { Bl3Option::Two },
            rounds: 150,
            // λ = 1e-2 keeps every random draw inside the local basin from
            // x⁰ = 0 even with lazy gradients (p < 1) — the boundary case is
            // pinned separately by bl1_far_from_basin_can_wander.
            lambda: 1e-2,
            target_gap: 0.0,
            seed: 42 + case as u64,
            ..RunConfig::default()
        };
        let out = run_federated(&f, &cfg).unwrap_or_else(|e| {
            panic!("case {case} ({algo}, {:?}, {:?}) errored: {e:#}", cfg.basis, cfg.hess_comp)
        });
        let first = out.history.records.first().unwrap().gap;
        let last = out.final_gap();
        let best = out.history.records.iter().map(|r| r.gap).fold(f64::INFINITY, f64::min);
        // Never blow up (the paper's theory is *local*: with lazy gradients
        // (p < 1) and aggressive unbiased compression the transient can
        // wander, so we assert boundedness always and progress via the best
        // gap seen).
        assert!(last.is_finite() && last < 1e3, "case {case} diverged: {last:.3e}");
        assert!(
            best < first * 0.9 || best < 1e-10,
            "case {case} ({algo}, basis {:?}, comp {}, p {}, tau {:?}) made no progress: {first:.3e} → best {best:.3e}",
            cfg.basis,
            cfg.hess_comp,
            cfg.p,
            cfg.tau,
        );
    }
}

/// Documents the boundary of BL1's *local* theory: on a near-separable shard
/// (few points, ‖x*‖ ≫ 1) with lazy gradients (p < 1), the estimator
/// `g = [H]_μ(z−w) + ∇f(w)` extrapolates a nearly-flat logistic and the
/// iterates wander — exactly why Theorems 4.9–4.11 assume a starting point
/// inside the basin. With p = 1 the same instance converges.
#[test]
fn bl1_far_from_basin_can_wander() {
    let f = fed(2, 12, 10, 3, 1025);
    let run = |p: f64| {
        let cfg = RunConfig {
            algorithm: Algorithm::Bl1,
            basis: Some(BasisKind::Standard),
            hess_comp: CompressorSpec::TopK(12),
            p,
            rounds: 300,
            lambda: 1e-3,
            target_gap: 1e-10,
            seed: 67,
            ..RunConfig::default()
        };
        run_federated(&f, &cfg).unwrap().final_gap()
    };
    assert!(run(1.0) <= 1e-10, "p=1 must converge even here");
    assert!(run(0.5) > 1e-6, "if lazy gradients converge here too, tighten the sweep above");
}

#[test]
fn edge_case_single_client() {
    let f = fed(1, 20, 8, 4, 55);
    for algo in [Algorithm::Bl1, Algorithm::Bl2, Algorithm::Bl3, Algorithm::Gd] {
        let cfg = RunConfig {
            algorithm: algo,
            hess_comp: CompressorSpec::TopK(8),
            rounds: if algo == Algorithm::Gd { 50_000 } else { 500 },
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        let out = run_federated(&f, &cfg).unwrap();
        assert!(out.final_gap() <= 1e-8, "{algo} single-client gap {}", out.final_gap());
    }
}

#[test]
fn edge_case_single_point_per_client() {
    let f = fed(4, 1, 6, 1, 56);
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        hess_comp: CompressorSpec::TopK(1),
        rounds: 600,
        target_gap: 1e-8,
        ..RunConfig::default()
    };
    let out = run_federated(&f, &cfg).unwrap();
    assert!(out.final_gap() <= 1e-8, "gap {}", out.final_gap());
}

#[test]
fn edge_case_tau_one() {
    let f = default_fed();
    let cfg = RunConfig {
        algorithm: Algorithm::Bl2,
        hess_comp: CompressorSpec::TopK(5),
        tau: Some(1),
        rounds: 4000,
        target_gap: 1e-8,
        ..RunConfig::default()
    };
    let out = run_federated(&f, &cfg).unwrap();
    assert!(out.final_gap() <= 1e-8, "gap {}", out.final_gap());
}

#[test]
fn noisy_data_breaks_exact_low_rank_but_methods_still_converge() {
    // Failure injection: data only approximately low-dimensional — the
    // subspace basis becomes lossy, the Hessian learner must absorb it.
    let f = FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 4,
        m_per_client: 30,
        dim: 12,
        intrinsic_dim: 4,
        noise: 0.05,
        seed: 57,
    });
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        basis: Some(BasisKind::Subspace),
        // Generous tolerance ⇒ the extracted basis keeps only dominant
        // directions and truly discards signal (the learner's decode is a
        // strict projection; convergence degrades to inexact-Newton linear).
        subspace_tol: 0.02,
        hess_comp: CompressorSpec::TopK(6),
        rounds: 6000,
        target_gap: 1e-6,
        ..RunConfig::default()
    };
    let out = run_federated(&f, &cfg).unwrap();
    assert!(out.final_gap() <= 1e-6, "gap {}", out.final_gap());
}

#[test]
fn lambda_sweep_second_order_insensitive_to_conditioning() {
    // The paper's motivation: Newton-type rates don't degrade as λ ↓ while
    // GD's do. Compare round counts to gap 1e-8 at λ = 1e-2 vs 1e-4.
    let f = default_fed();
    let run = |algo, lambda, rounds| {
        let cfg = RunConfig {
            algorithm: algo,
            hess_comp: CompressorSpec::TopK(5),
            lambda,
            rounds,
            target_gap: 1e-8,
            ..RunConfig::default()
        };
        run_federated(&f, &cfg).unwrap().history.records.len() as f64
    };
    let bl1_ratio = run(Algorithm::Bl1, 1e-4, 4000) / run(Algorithm::Bl1, 1e-2, 4000);
    let gd_ratio = run(Algorithm::Gd, 1e-4, 2_000_000) / run(Algorithm::Gd, 1e-2, 2_000_000);
    assert!(
        gd_ratio > 2.5 * bl1_ratio,
        "conditioning hurt GD {gd_ratio:.1}× vs BL1 {bl1_ratio:.1}× — expected a large gap"
    );
}

#[test]
fn libsvm_file_roundtrip_end_to_end() {
    // Real-data ingestion path: write a LibSVM file, load it, train on it.
    use basis_learn::data::{write_libsvm, LibsvmRecord};
    let fed_src = fed(3, 20, 8, 4, 321);
    let mut records = Vec::new();
    for c in &fed_src.clients {
        for i in 0..c.m() {
            let features = c
                .a
                .row(i)
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(j, &v)| (j + 1, v))
                .collect();
            records.push(LibsvmRecord { label: c.b[i], features });
        }
    }
    let path = std::env::temp_dir().join("bl_libsvm_e2e.libsvm");
    std::fs::write(&path, write_libsvm(&records)).unwrap();
    let fed = FederatedDataset::from_libsvm_file(&path, 3, None).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(fed.n_clients(), 3);
    assert_eq!(fed.dim(), 8);
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        hess_comp: CompressorSpec::TopK(4),
        rounds: 300,
        lambda: 1e-3,
        target_gap: 1e-9,
        ..RunConfig::default()
    };
    let out = run_federated(&fed, &cfg).unwrap();
    assert!(out.final_gap() <= 1e-9, "gap {}", out.final_gap());
}

#[test]
fn csv_outputs_are_written_and_well_formed() {
    let f = default_fed();
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        hess_comp: CompressorSpec::TopK(5),
        rounds: 20,
        target_gap: 0.0,
        ..RunConfig::default()
    };
    let out = run_federated(&f, &cfg).unwrap();
    let dir = std::env::temp_dir().join("bl_csv_test");
    let path = out.history.write_csv(&dir, "proptest").unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert_eq!(header.split(',').count(), 7);
    let rows: Vec<&str> = lines.collect();
    assert_eq!(rows.len(), 20);
    for row in rows {
        assert_eq!(row.split(',').count(), 7, "bad row: {row}");
        // Every numeric field parses.
        for field in row.split(',') {
            field.parse::<f64>().unwrap();
        }
    }
}
