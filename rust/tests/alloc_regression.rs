//! Steady-state rounds must not touch the heap.
//!
//! The whole point of the packed-`SymMat`/scratch-arena hot path is that
//! after warm-up (pool populated, scratch buffers at their steady
//! capacities) a full BL1 or FedNL round over the pooled `Lockstep`
//! transport performs **zero** heap allocations. This test installs the
//! crate's counting allocator as the process allocator and asserts exactly
//! that: the gross-allocated-bytes counter does not move across measured
//! rounds.
//!
//! Everything runs inside ONE `#[test]` function: the counters are
//! process-global, so a second concurrently-running test would pollute the
//! measurement window.

use basis_learn::bench_util::CountingAlloc;
use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, RunConfig};
use basis_learn::coordinator::{
    build_split, estimate_smoothness, native_locals, run_one_round, Env, ServerState,
};
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::linalg::Mat;
use basis_learn::obs::Obs;
use basis_learn::rng::Rng;
use basis_learn::transport::{client_rngs, Lockstep};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const WARMUP_ROUNDS: usize = 6;
const MEASURED_ROUNDS: usize = 6;

/// Run `WARMUP_ROUNDS` then `MEASURED_ROUNDS` rounds of `algorithm` on the
/// pooled lockstep transport; return gross bytes allocated during the
/// measured window.
fn steady_state_bytes(algorithm: Algorithm) -> u64 {
    // Full-rank features (intrinsic == dim) keep every Cholesky probe
    // comfortably positive-definite, so no round falls back to the
    // (allocating) eigendecomposition path.
    let fed = FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 4,
        m_per_client: 60,
        dim: 24,
        intrinsic_dim: 24,
        noise: 0.0,
        seed: 9,
    });
    let cfg = RunConfig {
        algorithm,
        rounds: WARMUP_ROUNDS + MEASURED_ROUNDS,
        lambda: 1e-2,
        hess_comp: CompressorSpec::TopK(24),
        target_gap: 0.0,
        ..RunConfig::default()
    };
    let locals = native_locals(&fed);
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    let smoothness = estimate_smoothness(&locals, cfg.lambda);
    let env = Env {
        locals: &locals,
        cfg: &cfg,
        d: fed.dim(),
        n: fed.n_clients(),
        smoothness,
        features,
        obs: Obs::noop(),
    };
    let (mut server, clients) = build_split(&env).expect("split");
    let mut transport = Lockstep::new(&locals, clients, client_rngs(cfg.seed, env.n))
        .with_pool(server.pool().cloned());
    let mut srv_rng = Rng::new(cfg.seed);
    for round in 0..WARMUP_ROUNDS {
        run_one_round(&env, server.as_mut(), &mut transport, round, &mut srv_rng)
            .expect("warm-up round");
    }
    let before = CountingAlloc::allocated_bytes();
    for round in WARMUP_ROUNDS..WARMUP_ROUNDS + MEASURED_ROUNDS {
        run_one_round(&env, server.as_mut(), &mut transport, round, &mut srv_rng)
            .expect("measured round");
    }
    CountingAlloc::allocated_bytes() - before
}

#[test]
fn bl1_and_fednl_steady_state_rounds_allocate_zero_bytes() {
    // The allocator wrapper must actually be installed, or the zero deltas
    // below would be vacuous.
    assert!(CountingAlloc::is_counting(), "counting allocator not installed");
    let setup_bytes = CountingAlloc::allocated_bytes();
    assert!(setup_bytes > 0, "counter never moved");

    let bl1 = steady_state_bytes(Algorithm::Bl1);
    assert_eq!(
        bl1, 0,
        "BL1 allocated {bl1} bytes across {MEASURED_ROUNDS} steady-state rounds"
    );

    let fednl = steady_state_bytes(Algorithm::FedNl);
    assert_eq!(
        fednl, 0,
        "FedNL allocated {fednl} bytes across {MEASURED_ROUNDS} steady-state rounds"
    );
}
