//! The self-audit: this crate must pass its own static-analysis gate.
//!
//! This is the test-suite twin of the CI step `repro audit` — a rule
//! violation anywhere in `rust/src` (or a drifted registry/doc) fails here
//! first, with the full finding list in the assertion message.

use basis_learn::audit::{report::render_table, run, AuditConfig};

#[test]
fn the_crate_audits_clean() {
    let report = run(&AuditConfig::for_this_crate()).expect("self-audit runs");
    assert!(
        report.clean(),
        "repro audit found violations in this crate:\n{}",
        render_table(&report)
    );
    // The scan actually covered the tree (guards against a silently empty
    // walk making the gate vacuous).
    assert!(report.files_scanned > 30, "only {} files scanned", report.files_scanned);
    assert!(report.allows_honored > 10, "allows: {}", report.allows_honored);
}
