//! Fixture-driven tests for `repro audit`'s rule engine.
//!
//! Each fixture under `tests/audit_fixtures/` is a tiny crate-shaped tree
//! (`src/`, optionally `docs/TRACING.md` and `tests/transport_equivalence.rs`)
//! with violations — or deliberate near-misses — seeded in known places.
//! Cargo does not compile `.rs` files in `tests/` *subdirectories*, so the
//! fixtures are plain data as far as the build is concerned.

use basis_learn::audit::{run, AuditConfig, AuditReport};
use std::path::PathBuf;

fn audit_fixture(name: &str) -> AuditReport {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/audit_fixtures")
        .join(name);
    run(&AuditConfig::for_root(root)).expect("fixture audit runs")
}

fn rules_of(report: &AuditReport) -> Vec<&'static str> {
    report.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn panic_hit_fixture_trips_panic_and_determinism_rules() {
    let report = audit_fixture("panic_hit");
    let rules = rules_of(&report);
    // .unwrap() and todo! on library paths.
    assert_eq!(rules.iter().filter(|r| **r == "panic-safety").count(), 2, "{rules:?}");
    // HashMap appears in the import and in a signature.
    assert_eq!(rules.iter().filter(|r| **r == "determinism-hash").count(), 2, "{rules:?}");
    // Instant::now() fires; the bare `use std::time::Instant` import must not.
    assert_eq!(rules.iter().filter(|r| **r == "determinism-clock").count(), 1, "{rules:?}");
    // Rng::new(0x1234) has no seed-named argument.
    assert_eq!(rules.iter().filter(|r| **r == "determinism-rng").count(), 1, "{rules:?}");
    assert!(!report.clean());
    // Findings are sorted by (file, line, rule).
    let mut sorted = report.findings.clone();
    sorted.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    assert_eq!(
        report.findings.iter().map(|f| (f.line, f.rule)).collect::<Vec<_>>(),
        sorted.iter().map(|f| (f.line, f.rule)).collect::<Vec<_>>()
    );
}

#[test]
fn justified_allow_suppresses_and_is_counted() {
    let report = audit_fixture("allow_escape");
    assert!(report.clean(), "{:?}", report.findings);
    assert_eq!(report.allows_honored, 1);
    assert_eq!(report.files_scanned, 1);
}

#[test]
fn strings_comments_tests_and_lookalikes_do_not_fire() {
    let report = audit_fixture("false_positive_guard");
    assert!(report.clean(), "{:?}", report.findings);
    assert_eq!(report.allows_honored, 0);
}

#[test]
fn charge_policy_violations_are_caught() {
    let report = audit_fixture("bad_kinds");
    let bit: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "bit-accounting").collect();
    let msgs: Vec<&str> = bit.iter().map(|f| f.msg.as_str()).collect();
    assert_eq!(bit.len(), 5, "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("\"mystery\"")), "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("\"paid\"") && m.contains("BitCost::zero()")),
        "{msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("\"free_ride\"") && m.contains("non-zero")),
        "{msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("\"dead\"") && m.contains("no push site")),
        "{msgs:?}"
    );
    assert!(msgs.iter().any(|m| m.contains("string literal")), "{msgs:?}");
    // The well-behaved "ok_kind" site produces nothing.
    assert!(!msgs.iter().any(|m| m.contains("ok_kind")), "{msgs:?}");
    // The documented registry keeps registry-sync quiet.
    assert!(
        !report.findings.iter().any(|f| f.rule == "registry-sync"),
        "{:?}",
        report.findings
    );
}

#[test]
fn drifted_algorithm_registries_are_caught() {
    let report = audit_fixture("unregistered_algo");
    let sync: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "registry-sync").collect();
    let msgs: Vec<&str> = sync.iter().map(|f| f.msg.as_str()).collect();
    assert_eq!(sync.len(), 2, "{msgs:?}");
    assert!(
        msgs.iter().any(|m| m.contains("`Beta`") && m.contains("Algorithm::all()")),
        "{msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("`Beta`") && m.contains("transport_equivalence")),
        "{msgs:?}"
    );
    // Alpha is registered and exercised: no findings mention it.
    assert!(!msgs.iter().any(|m| m.contains("`Alpha`")), "{msgs:?}");
}

#[test]
fn codec_table_drift_is_caught() {
    let report = audit_fixture("codec_drift");
    let codec: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "codec-sync").collect();
    let msgs: Vec<&str> = codec.iter().map(|f| f.msg.as_str()).collect();
    assert_eq!(codec.len(), 10, "{msgs:?}");
    // "alpha" appears twice in the table: one duplicate-id finding.
    assert!(
        msgs.iter().any(|m| m.contains("\"alpha\"") && m.contains("more than once")),
        "{msgs:?}"
    );
    // "beta" and "gamma" are registered but missing from the table.
    assert!(
        msgs.iter().any(|m| m.contains("\"beta\"") && m.contains("no wire id")),
        "{msgs:?}"
    );
    assert!(
        msgs.iter().any(|m| m.contains("\"gamma\"") && m.contains("no wire id")),
        "{msgs:?}"
    );
    // "delta" is a wire id with no registered kind behind it.
    assert!(
        msgs.iter().any(|m| m.contains("\"delta\"") && m.contains("not in the kinds registry")),
        "{msgs:?}"
    );
    // Frame-level drift: byte disagreement between enum and table.
    assert!(
        msgs.iter().any(|m| m.contains("FrameKind::Packet = 2") && m.contains("(\"packet\", 3)")),
        "{msgs:?}"
    );
    // Duplicate wire byte inside the frame table.
    assert!(
        msgs.iter()
            .any(|m| m.contains("frame byte 3") && m.contains("\"packet\"") && m.contains("\"bye\"")),
        "{msgs:?}"
    );
    // Reserved byte 0 must stay unassigned.
    assert!(msgs.iter().any(|m| m.contains("\"zero\"") && m.contains("reserved byte 0")), "{msgs:?}");
    // A variant without an explicit discriminant risks silent renumbering.
    assert!(
        msgs.iter().any(|m| m.contains("FrameKind::Bye") && m.contains("no explicit discriminant")),
        "{msgs:?}"
    );
    // A variant missing from the table cannot cross the codec.
    assert!(
        msgs.iter().any(|m| m.contains("FrameKind::Gone") && m.contains("no FRAME_KINDS entry")),
        "{msgs:?}"
    );
    // An orphan table entry has no variant behind its byte.
    assert!(
        msgs.iter().any(|m| m.contains("\"zero\"") && m.contains("no FrameKind enum variant")),
        "{msgs:?}"
    );
    // The drift is the only problem: charges are honored, kinds documented.
    assert_eq!(report.findings.len(), 10, "{:?}", report.findings);
}

#[test]
fn fixtures_without_a_codec_table_stay_silent_on_codec_sync() {
    for fixture in ["bad_kinds", "unregistered_algo", "false_positive_guard"] {
        let report = audit_fixture(fixture);
        assert!(
            !report.findings.iter().any(|f| f.rule == "codec-sync"),
            "{fixture}: {:?}",
            report.findings
        );
    }
}

#[test]
fn escape_hygiene_is_enforced() {
    let report = audit_fixture("stale_allows");
    let rules = rules_of(&report);
    assert_eq!(rules.iter().filter(|r| **r == "unused-allow").count(), 1, "{rules:?}");
    assert_eq!(rules.iter().filter(|r| **r == "allow-syntax").count(), 2, "{rules:?}");
    assert_eq!(report.findings.len(), 3, "{:?}", report.findings);
    assert_eq!(report.allows_honored, 0);
}

#[test]
fn missing_src_dir_is_an_error_not_a_clean_report() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/audit_fixtures/no_such_fixture");
    assert!(run(&AuditConfig::for_root(root)).is_err());
}
