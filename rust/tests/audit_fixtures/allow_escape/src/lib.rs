//! Fixture: the same violation shape as `panic_hit`, but escaped with a
//! justified inline allow — the audit must come back clean and count the
//! suppression.

pub fn last_pushed(items: &mut Vec<u32>) -> u32 {
    items.push(7);
    // audit:allow(panic-safety): the element was pushed on the previous line.
    *items.last().unwrap()
}
