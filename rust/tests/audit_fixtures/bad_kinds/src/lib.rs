//! Fixture: a self-contained message-kind registry plus push sites that
//! violate the charge policy in every way bit-accounting checks.

pub enum Direction {
    Up,
    Down,
}

pub enum Charge {
    Charged,
    Free,
    Mixed,
}

pub struct Kind {
    pub name: &'static str,
    pub dir: Direction,
    pub charge: Charge,
}

pub const KINDS: &[Kind] = &[
    // Never pushed anywhere: "dead vocabulary" finding.
    Kind { name: "dead", dir: Direction::Up, charge: Charge::Charged },
    Kind { name: "free_ride", dir: Direction::Down, charge: Charge::Free },
    Kind { name: "ok_kind", dir: Direction::Up, charge: Charge::Charged },
    Kind { name: "paid", dir: Direction::Up, charge: Charge::Charged },
];

pub struct BitCost(f64);
impl BitCost {
    pub fn zero() -> Self {
        BitCost(0.0)
    }
    pub fn floats(n: usize) -> Self {
        BitCost(64.0 * n as f64)
    }
}

pub struct Packet;
impl Packet {
    pub fn push_vector(&mut self, _kind: &'static str, _v: Vec<f64>, _cost: BitCost) {}
}

pub fn exercise(p: &mut Packet, computed: &'static str) {
    // Fine: registered, charged, non-zero cost.
    p.push_vector("ok_kind", vec![1.0], BitCost::floats(1));
    // Unregistered kind: must be caught.
    p.push_vector("mystery", vec![1.0], BitCost::floats(1));
    // Charged kind pushed free: must be caught.
    p.push_vector("paid", vec![1.0], BitCost::zero());
    // Free kind pushed with a cost: must be caught.
    p.push_vector("free_ride", vec![1.0], BitCost::floats(1));
    // Computed (non-literal) kind: must be caught.
    p.push_vector(computed, vec![1.0], BitCost::zero());
}
