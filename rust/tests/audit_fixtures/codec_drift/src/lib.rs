//! Fixture: a self-contained message-kind registry plus a drifted
//! `WIRE_KINDS` codec table exercising every codec-sync check. The push
//! sites honor every charge policy so bit-accounting stays quiet and the
//! test isolates codec-sync findings.

pub enum Direction {
    Up,
    Down,
}

pub enum Charge {
    Charged,
    Free,
}

pub struct Kind {
    pub name: &'static str,
    pub dir: Direction,
    pub charge: Charge,
}

pub const KINDS: &[Kind] = &[
    Kind { name: "alpha", dir: Direction::Up, charge: Charge::Charged },
    // Missing from WIRE_KINDS: must be caught (one finding each).
    Kind { name: "beta", dir: Direction::Down, charge: Charge::Free },
    Kind { name: "gamma", dir: Direction::Up, charge: Charge::Charged },
];

// A drifted codec table: "alpha" twice (duplicate id), "delta" orphaned
// (not registered), "beta"/"gamma" absent.
pub const WIRE_KINDS: &[&str] = &["alpha", "alpha", "delta"];

// The frame level of the codec, drifted to exercise every frame check.
pub enum FrameKind {
    Hello = 1,
    // Table assigns byte 3 instead: must be caught (byte disagreement).
    Packet = 2,
    // No explicit discriminant: must be caught (implicit renumbering risk).
    Bye,
    // Missing from FRAME_KINDS: must be caught.
    Gone = 4,
}

pub const FRAME_KINDS: &[(&str, u8)] = &[
    ("hello", 1),
    ("packet", 3),
    // Duplicate byte 3: must be caught.
    ("bye", 3),
    // Reserved byte 0 AND no FrameKind variant: two findings.
    ("zero", 0),
];

pub struct BitCost(f64);
impl BitCost {
    pub fn zero() -> Self {
        BitCost(0.0)
    }
    pub fn floats(n: usize) -> Self {
        BitCost(64.0 * n as f64)
    }
}

pub struct Packet;
impl Packet {
    pub fn push_vector(&mut self, _kind: &'static str, _v: Vec<f64>, _cost: BitCost) {}
}

pub fn exercise(p: &mut Packet) {
    p.push_vector("alpha", vec![1.0], BitCost::floats(1));
    p.push_vector("beta", vec![1.0], BitCost::zero());
    p.push_vector("gamma", vec![1.0], BitCost::floats(1));
}

/// A non-declaration use of the table: must not be parsed as a second
/// codec table (only `const WIRE_KINDS` declaration sites count).
pub fn wire_id(kind: &str) -> Option<usize> {
    WIRE_KINDS.iter().position(|k| *k == kind)
}
