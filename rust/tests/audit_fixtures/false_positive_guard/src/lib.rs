//! Fixture: rule-trigger *text* in places the audit must NOT flag —
//! string literals, comments, test-only code, and identifiers that merely
//! resemble the dangerous ones.

// A comment mentioning .unwrap(), HashMap, Instant::now() and panic! is fine.

pub fn strings() -> &'static str {
    "call .unwrap() on a HashMap at Instant::now() or panic!(\"boom\")"
}

pub fn raw_strings() -> &'static str {
    r#"HashMap::new().unwrap() inside a raw string with a "quote""#
}

/// `unwrap_or` / `unwrap_or_else` / `unwrap_or_default` are infallible.
pub fn combinators(x: Option<u32>) -> u32 {
    x.unwrap_or(0).max(x.unwrap_or_else(|| 1)).max(x.unwrap_or_default())
}

/// `unreachable!` and asserts state invariants; they are exempt.
pub fn invariants(x: u32) -> u32 {
    assert!(x < 10, "precondition");
    match x {
        0..=9 => x,
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_code_may_unwrap() {
        let mut m = HashMap::new();
        m.insert("k", 1);
        assert_eq!(*m.get("k").unwrap(), 1);
    }
}
