//! Fixture: library-path violations the audit must catch.

use std::collections::HashMap;
use std::time::Instant;

pub fn lookup(map: &HashMap<String, u32>, key: &str) -> u32 {
    // An unwrap on a library path: panic-safety must fire.
    *map.get(key).unwrap()
}

pub fn timed() -> f64 {
    // A wall-clock read outside obs/: determinism-clock must fire.
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}

pub fn ambient_random() -> u64 {
    // An unseeded stream: determinism-rng must fire.
    let mut rng = Rng::new(0x1234);
    rng.next()
}

pub fn unfinished() {
    todo!("panic-safety flags todo! too")
}

pub struct Rng(u64);
impl Rng {
    pub fn new(state: u64) -> Self {
        Rng(state)
    }
    pub fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1);
        self.0
    }
}
