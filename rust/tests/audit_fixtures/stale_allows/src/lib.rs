//! Fixture: escape-hygiene violations — a stale allow, an unknown rule id,
//! and a directive without a justification.

// audit:allow(panic-safety): nothing here actually panics any more.
pub fn fine() -> u32 {
    1
}

// audit:allow(no-such-rule): the rule id is not in the registry.
pub fn also_fine() -> u32 {
    2
}

// audit:allow(determinism-hash)
pub fn still_fine() -> u32 {
    3
}
