//! Fixture: an `Algorithm` enum whose registries have drifted — `Beta` is
//! missing from `fn all()` and from the transport-equivalence test.

#[derive(Clone, Copy, Debug)]
pub enum Algorithm {
    Alpha,
    Beta,
}

impl Algorithm {
    pub fn all() -> &'static [Algorithm] {
        &[Algorithm::Alpha]
    }
}
