// Fixture equivalence test: covers Alpha only; Beta is missing.

#[test]
fn alpha_equivalence() {
    let _ = "Algorithm::Alpha";
    let _alpha = Alpha;
}

struct Alpha;
