//! The observability layer's two contracts (ISSUE 6):
//!
//! 1. **Neutrality** — tracing observes, never participates: a run traced
//!    through a [`JsonlRecorder`] must produce a byte-identical `History`
//!    (and final iterate) to the same run untraced, for second-order and
//!    FedNL-family cells on *both* transport backends.
//! 2. **Reconciliation** — the trace is exact, not approximate: per-round
//!    uplink/downlink bit sums over the trace's per-message events equal
//!    the run's `CommTally` (and the `History`'s cumulative per-node bits
//!    × n) with exact f64 equality. Bit costs are integer-valued and
//!    n = 4 divides exactly, so there is no tolerance to hide behind.

use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, RunConfig, TransportSpec};
use basis_learn::coordinator::{
    build_split, estimate_smoothness, native_locals, run_federated, run_federated_traced,
    run_one_round, CommTally, Env,
};
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::obs::{chrome_trace, load_trace, JsonlRecorder, Obs, Recorder, TraceRow};
use basis_learn::rng::Rng;
use basis_learn::sweep::{run_cells_obs, DatasetRef, Json, SweepSpec};
use basis_learn::transport::{client_rngs, Lockstep};

fn fed(seed: u64) -> FederatedDataset {
    FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 4, // power of two: per-node bit divisions stay exact
        m_per_client: 25,
        dim: 10,
        intrinsic_dim: 4,
        noise: 0.0,
        seed,
    })
}

fn cfg_bl1() -> RunConfig {
    RunConfig {
        algorithm: Algorithm::Bl1,
        rounds: 15,
        hess_comp: CompressorSpec::TopK(4),
        model_comp: CompressorSpec::TopK(5),
        p: 0.5,
        lambda: 1e-3,
        target_gap: 0.0,
        seed: 7,
        ..RunConfig::default()
    }
}

fn cfg_fednl() -> RunConfig {
    RunConfig {
        algorithm: Algorithm::FedNl,
        rounds: 12,
        hess_comp: CompressorSpec::RankR(1),
        lambda: 1e-3,
        target_gap: 0.0,
        seed: 7,
        ..RunConfig::default()
    }
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("bl_obs_it_{tag}_{}", std::process::id()))
}

/// Sum the traced wire bits for one (cell?, round, direction).
fn bits_sum(rows: &[TraceRow], cell: Option<usize>, round: usize, dir: &str) -> f64 {
    rows.iter()
        .filter(|r| {
            r.is_bits()
                && (cell.is_none() || r.cell == cell)
                && r.round == Some(round)
                && r.dir.as_deref() == Some(dir)
        })
        .map(|r| r.bits.unwrap())
        .sum()
}

#[test]
fn tracing_is_neutral_for_bl1_and_fednl_on_both_backends() {
    for (tag, base) in [("bl1", cfg_bl1()), ("fednl", cfg_fednl())] {
        for (ti, transport) in [TransportSpec::Lockstep, TransportSpec::Threaded(3)]
            .into_iter()
            .enumerate()
        {
            let cfg = RunConfig { transport, ..base.clone() };
            let f = fed(2026);
            let plain = run_federated(&f, &cfg).unwrap();
            let path = tmp_path(&format!("neutral_{tag}_{ti}"));
            let rec = JsonlRecorder::create(&path).unwrap();
            let traced = run_federated_traced(&f, &cfg, &rec).unwrap();
            rec.flush().unwrap();
            // Byte-identical history: every f64 must match exactly.
            assert_eq!(
                plain.history.records, traced.history.records,
                "{tag}/{transport}: tracing changed the history"
            );
            assert_eq!(plain.history.setup_bits_per_node, traced.history.setup_bits_per_node);
            assert_eq!(plain.history.label, traced.history.label);
            assert_eq!(plain.x_final, traced.x_final);
            // ... and the traced run really did record something substantial.
            let rows = load_trace(&path).unwrap().rows;
            assert!(
                rows.len() > cfg.rounds * 4,
                "{tag}/{transport}: only {} trace events",
                rows.len()
            );
            std::fs::remove_file(&path).unwrap();
        }
    }
}

#[test]
fn per_round_trace_bits_reconcile_with_comm_tally() {
    let f = fed(11);
    let cfg = cfg_bl1();
    let locals = native_locals(&f);
    let features: Vec<_> = f.clients.iter().map(|c| Some(c.a.clone())).collect();
    let smoothness = estimate_smoothness(&locals, cfg.lambda);
    let path = tmp_path("tally");
    let rec = JsonlRecorder::create(&path).unwrap();
    let env = Env {
        locals: &locals,
        cfg: &cfg,
        d: f.dim(),
        n: f.n_clients(),
        smoothness,
        features,
        obs: Obs::new(&rec),
    };
    let (mut server, clients) = build_split(&env).unwrap();
    let mut transport =
        Lockstep::new(&locals, clients, client_rngs(cfg.seed, env.n)).with_obs(env.obs);
    let mut rng = Rng::new(cfg.seed);
    let mut tallies: Vec<CommTally> = Vec::new();
    for round in 0..cfg.rounds {
        tallies
            .push(run_one_round(&env, server.as_mut(), &mut transport, round, &mut rng).unwrap());
    }
    rec.flush().unwrap();
    let rows = load_trace(&path).unwrap().rows;
    // Exact reconciliation, round by round, direction by direction.
    for (round, tally) in tallies.iter().enumerate() {
        assert_eq!(bits_sum(&rows, None, round, "up"), tally.up_bits, "round {round} uplink");
        assert_eq!(
            bits_sum(&rows, None, round, "down"),
            tally.down_bits,
            "round {round} downlink"
        );
    }
    // Every wire event is attributable: direction, client, message kind.
    for r in rows.iter().filter(|r| r.is_bits()) {
        assert!(r.client.is_some() && r.kind.is_some(), "unattributed bits event: {r:?}");
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn sweep_trace_attributes_cells_and_reconciles_histories() {
    // A sweep over ≥ 2 algorithms (the acceptance-criteria scenario).
    let spec = SweepSpec {
        algos: vec![Algorithm::Bl1, Algorithm::FedNl],
        datasets: vec![DatasetRef::Synthetic(SyntheticSpec {
            n_clients: 4,
            m_per_client: 20,
            dim: 8,
            intrinsic_dim: 3,
            noise: 0.0,
            seed: 0,
        })],
        hess_comps: vec![CompressorSpec::TopK(3)],
        seeds: vec![1],
        base: RunConfig { rounds: 10, target_gap: 0.0, ..RunConfig::default() },
        ..SweepSpec::default()
    };
    let cells = spec.expand();
    assert_eq!(cells.len(), 2);
    let path = tmp_path("sweep");
    let rec = JsonlRecorder::create(&path).unwrap();
    let results = run_cells_obs(&cells, 2, Obs::new(&rec), |_| {});
    rec.flush().unwrap();
    let rows = load_trace(&path).unwrap().rows;
    // Every event in a sweep trace is attributed to its cell.
    for r in &rows {
        assert!(r.cell.is_some(), "cell-less event: {} {}", r.ev, r.name);
    }
    assert_eq!(rows.iter().filter(|r| r.name == "cell").count(), 2, "one cell span per cell");
    assert_eq!(
        rows.iter().filter(|r| r.name == "dataset_cache").count(),
        2,
        "one cache mark per cell"
    );
    // Per-cell, per-round: trace bits == history's per-node cumulative
    // deltas × n, exactly (n = 4, so the division roundtrips losslessly).
    for res in &results {
        let h = res.require_history().unwrap();
        let n = 4.0;
        let (mut prev_up, mut prev_down) = (0.0, 0.0);
        for record in &h.records {
            assert_eq!(
                bits_sum(&rows, Some(res.id), record.round, "up"),
                (record.bits_up_per_node - prev_up) * n,
                "cell {} round {} uplink",
                res.id,
                record.round
            );
            assert_eq!(
                bits_sum(&rows, Some(res.id), record.round, "down"),
                (record.bits_down_per_node - prev_down) * n,
                "cell {} round {} downlink",
                res.id,
                record.round
            );
            prev_up = record.bits_up_per_node;
            prev_down = record.bits_down_per_node;
        }
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn chrome_export_round_trips_a_real_trace() {
    let f = fed(5);
    let cfg = RunConfig { rounds: 5, ..cfg_fednl() };
    let path = tmp_path("chrome");
    let rec = JsonlRecorder::create(&path).unwrap();
    run_federated_traced(&f, &cfg, &rec).unwrap();
    rec.flush().unwrap();
    let rows = load_trace(&path).unwrap().rows;
    let text = chrome_trace(&rows);
    let parsed = Json::parse(&text).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let count = |ph: &str| {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
            .count()
    };
    let spans = rows.iter().filter(|r| r.is_span()).count();
    assert!(spans > 0);
    assert_eq!(count("X"), spans, "one complete event per span");
    assert_eq!(count("i"), rows.len() - spans, "one instant per bits/mark event");
    assert!(count("M") >= 2, "thread_name metadata for server + clients");
    std::fs::remove_file(&path).unwrap();
}
