//! Bitwise contracts of the allocation-free hot path.
//!
//! Two families of properties, both asserted with exact `f64` equality
//! (`==`, not tolerances) because the round loop substitutes these kernels
//! on trajectories `tests/transport_equivalence.rs` pins byte-identical:
//!
//! 1. `SymMat ↔ Mat` round-trips are lossless, and every packed kernel
//!    (`add_scaled`, `add_diag`, `matvec`, `gram_scaled_from`,
//!    `SymCholesky`) matches its dense counterpart bit for bit.
//! 2. Every `*_into` kernel equals its allocating counterpart bit for bit
//!    across rectangular and degenerate shapes — linalg, bases,
//!    compressors (twin RNG streams), oracles, and RNG sampling.

use basis_learn::basis::{
    subspace::orthonormal_cols, BasisScratch, HessianBasis, PsdBasis, StandardBasis,
    SubspaceBasis, SymTriBasis,
};
use basis_learn::compressors::{CompressScratch, CompressorSpec};
use basis_learn::linalg::{
    cholesky_solve, cholesky_solve_packed, packed_len, sub_into, CholeskyFactor, Mat,
    SymCholesky, SymMat, Vector,
};
use basis_learn::problem::{LocalProblem, LogisticProblem, OracleScratch};
use basis_learn::rng::Rng;

/// Rectangular and degenerate shapes every `*_into` kernel must survive.
const SHAPES: &[(usize, usize)] = &[(0, 0), (1, 1), (1, 7), (7, 1), (3, 5), (5, 3), (8, 8)];

fn random_mat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

fn random_vec(n: usize, rng: &mut Rng) -> Vector {
    (0..n).map(|_| rng.normal()).collect()
}

fn random_sym(n: usize, rng: &mut Rng) -> Mat {
    let mut a = random_mat(n, n, rng);
    a.symmetrize();
    a
}

fn random_spd(n: usize, rng: &mut Rng) -> Mat {
    let b = random_mat(n, n, rng);
    let mut a = b.transpose().matmul(&b);
    a.add_diag(0.5 * (n as f64) + 1.0);
    a
}

// ── SymMat ↔ Mat round trips ─────────────────────────────────────────────

#[test]
fn symmat_roundtrip_is_exact() {
    let mut rng = Rng::new(41);
    for n in [0usize, 1, 2, 3, 7, 16, 33] {
        let a = random_sym(n, &mut rng);
        let packed = SymMat::from_mat(&a);
        assert_eq!(packed.data().len(), packed_len(n));
        // Fresh-allocation unpack.
        assert_eq!(packed.to_mat(), a, "to_mat n={n}");
        // Storage-reusing unpack, including shrink from a larger previous use.
        let mut out = Mat::zeros(n + 3, n + 3);
        packed.unpack_into(&mut out);
        assert_eq!(out, a, "unpack_into n={n}");
        // Storage-reusing re-pack.
        let mut repacked = SymMat::zeros(n + 2);
        repacked.pack_from(&a);
        assert_eq!(repacked, packed, "pack_from n={n}");
    }
}

#[test]
fn symmat_packed_ops_match_dense_bitwise() {
    let mut rng = Rng::new(42);
    for n in [0usize, 1, 2, 5, 12] {
        let a = random_sym(n, &mut rng);
        let b = random_sym(n, &mut rng);
        let alpha = rng.normal();

        // add_scaled: packed entries must equal the dense lower triangle.
        let mut pa = SymMat::from_mat(&a);
        pa.add_scaled(alpha, &SymMat::from_mat(&b));
        let mut da = a.clone();
        da.add_scaled(alpha, &b);
        for i in 0..n {
            for j in 0..=i {
                assert!(pa.get(i, j) == da[(i, j)], "add_scaled ({i},{j}) n={n}");
            }
        }

        // add_diag.
        pa.add_diag(alpha);
        da.add_diag(alpha);
        for i in 0..n {
            assert!(pa.get(i, i) == da[(i, i)], "add_diag ({i}) n={n}");
        }

        // matvec: same accumulation order as the packed walk promises.
        let x = random_vec(n, &mut rng);
        let yp = SymMat::from_mat(&a).matvec(&x);
        let mut yp2 = vec![f64::NAN; 3]; // dirty storage must be overwritten
        SymMat::from_mat(&a).matvec_into(&x, &mut yp2);
        assert_eq!(yp, yp2, "matvec vs matvec_into n={n}");
        assert_eq!(yp.len(), n);
    }
}

#[test]
fn gram_scaled_from_matches_dense_bitwise() {
    let mut rng = Rng::new(43);
    for &(m, d) in SHAPES {
        let a = random_mat(m, d, &mut rng);
        let mut s = random_vec(m, &mut rng);
        if m > 2 {
            s[1] = 0.0; // exercise the zero-weight skip path in both kernels
        }
        let dense = a.gram_scaled(&s);
        let mut packed = SymMat::zeros(d + 1); // dirty, wrong-order start
        packed.gram_scaled_from(&a, &s);
        assert_eq!(packed.n(), d);
        for i in 0..d {
            for j in 0..=i {
                assert!(
                    packed.get(i, j) == dense[(i, j)],
                    "gram ({i},{j}) m={m} d={d}: {} vs {}",
                    packed.get(i, j),
                    dense[(i, j)]
                );
            }
        }
        // And the dense `_into` variant is bitwise-equal too.
        let mut dense2 = Mat::zeros(1, 1);
        a.gram_scaled_into(&s, &mut dense2);
        assert_eq!(dense, dense2, "gram_scaled_into m={m} d={d}");
    }
}

#[test]
fn packed_cholesky_matches_dense_factor_bitwise() {
    let mut rng = Rng::new(44);
    let mut f = SymCholesky::new();
    let mut x = Vec::new();
    for n in [0usize, 1, 2, 4, 9, 21] {
        let a = random_spd(n, &mut rng);
        let b = random_vec(n, &mut rng);
        let dense = CholeskyFactor::new(&a).expect("SPD by construction");
        let xd = dense.solve(&b);
        let xo = cholesky_solve(&a, &b).expect("SPD by construction");
        assert_eq!(xd, xo, "one-shot dense n={n}");

        f.factor(&a).expect("SPD by construction");
        f.solve_into(&b, &mut x);
        assert_eq!(x, xd, "dense-input packed solve n={n}");

        let pa = SymMat::from_mat(&a);
        f.factor_sym(&pa).expect("SPD by construction");
        f.solve_into(&b, &mut x);
        assert_eq!(x, xd, "packed-input packed solve n={n}");
        let xp = cholesky_solve_packed(&pa, &b).expect("SPD by construction");
        assert_eq!(xp, xd, "one-shot packed n={n}");
    }
    // Failure parity: the packed factor rejects exactly what the dense does.
    let indef = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
    assert!(CholeskyFactor::new(&indef).is_err());
    assert!(f.factor(&indef).is_err());
    assert!(f.factor_sym(&SymMat::from_mat(&indef)).is_err());
}

// ── Mat `*_into` kernels vs allocating counterparts ──────────────────────

#[test]
fn mat_into_kernels_match_allocating_bitwise() {
    let mut rng = Rng::new(45);
    for &(m, d) in SHAPES {
        let a = random_mat(m, d, &mut rng);
        let x = random_vec(d, &mut rng);
        let xt = random_vec(m, &mut rng);
        // Dirty target reused across every kernel: stale shape and contents
        // must never leak through.
        let mut out = Mat::from_fn(2, 3, |_, _| f64::NAN);

        a.transpose_into(&mut out);
        assert_eq!(out, a.transpose(), "transpose {m}x{d}");

        let bt = random_mat(d, m, &mut rng);
        a.matmul_into(&bt, &mut out);
        assert_eq!(out, a.matmul(&bt), "matmul {m}x{d}");

        let mut v = vec![f64::NAN; 2];
        a.matvec_into(&x, &mut v);
        assert_eq!(v, a.matvec(&x), "matvec {m}x{d}");
        a.matvec_t_into(&xt, &mut v);
        assert_eq!(v, a.matvec_t(&xt), "matvec_t {m}x{d}");

        for j in 0..d {
            a.col_into(j, &mut v);
            assert_eq!(v, a.col(j), "col {j} of {m}x{d}");
        }

        let b = random_mat(m, d, &mut rng);
        let mut diff = Mat::zeros(1, 1);
        diff.sub_from(&a, &b);
        assert_eq!(diff, &a - &b, "sub_from {m}x{d}");

        let alpha = rng.normal();
        let mut scaled = Mat::zeros(1, 1);
        scaled.scale_from(&a, alpha);
        assert_eq!(scaled, &a * alpha, "scale_from {m}x{d}");

        let mut copy = Mat::zeros(3, 2);
        copy.copy_from(&a);
        assert_eq!(copy, a, "copy_from {m}x{d}");
    }
}

#[test]
fn vector_sub_into_matches_sub() {
    let mut rng = Rng::new(46);
    for n in [0usize, 1, 5, 17] {
        let a = random_vec(n, &mut rng);
        let b = random_vec(n, &mut rng);
        let mut out = vec![f64::NAN; 2];
        sub_into(&a, &b, &mut out);
        assert_eq!(out, basis_learn::linalg::sub(&a, &b), "n={n}");
    }
}

// ── basis `*_into` kernels ───────────────────────────────────────────────

#[test]
fn basis_into_kernels_match_allocating_bitwise() {
    let mut rng = Rng::new(47);
    for d in [1usize, 2, 6, 13] {
        let r = (d / 2).max(1);
        let bases: Vec<Box<dyn HessianBasis>> = vec![
            Box::new(StandardBasis::new(d)),
            Box::new(SymTriBasis::new(d)),
            Box::new(SubspaceBasis::new(orthonormal_cols(d, r, &mut rng))),
            Box::new(PsdBasis::new(d)),
        ];
        let h = random_sym(d, &mut rng);
        let g = random_vec(d, &mut rng);
        let mut scratch = BasisScratch::default();
        for basis in &bases {
            let name = basis.name();

            let coeff = basis.encode(&h);
            let mut coeff2 = Mat::from_fn(1, 2, |_, _| f64::NAN);
            basis.encode_into(&h, &mut coeff2, &mut scratch);
            assert_eq!(coeff, coeff2, "encode {name} d={d}");

            let dec = basis.decode(&coeff);
            let mut dec2 = Mat::from_fn(2, 1, |_, _| f64::NAN);
            basis.decode_into(&coeff, &mut dec2, &mut scratch);
            assert_eq!(dec, dec2, "decode {name} d={d}");

            let gc = basis.encode_grad(&g);
            let mut gc2 = vec![f64::NAN; 1];
            basis.encode_grad_into(&g, &mut gc2);
            assert_eq!(gc, gc2, "encode_grad {name} d={d}");

            let gd = basis.decode_grad(&gc);
            let mut gd2 = vec![f64::NAN; 1];
            basis.decode_grad_into(&gc, &mut gd2);
            assert_eq!(gd, gd2, "decode_grad {name} d={d}");
        }
    }
}

// ── compressor `*_into` kernels (twin RNG streams) ───────────────────────

#[test]
fn compressor_into_kernels_match_allocating_bitwise() {
    let specs = [
        CompressorSpec::Identity,
        CompressorSpec::TopK(5),
        CompressorSpec::RandK(5),
    ];
    for d in [1usize, 3, 8] {
        for spec in &specs {
            let mut rng = Rng::new(48);
            let h = random_sym(d, &mut rng);
            let comp = spec.build_mat(d);
            // Twin RNG streams: the `_into` path must draw identically.
            let mut r1 = rng.derive(1);
            let mut r2 = rng.derive(1);
            let (c, cost) = comp.compress(&h, &mut r1);
            let mut c2 = Mat::from_fn(1, 2, |_, _| f64::NAN);
            let mut scratch = CompressScratch::default();
            let cost2 = comp.compress_mat_into(&h, &mut c2, &mut scratch, &mut r2);
            assert_eq!(c, c2, "compress_mat {spec:?} d={d}");
            assert_eq!(cost, cost2, "mat cost {spec:?} d={d}");
            // RNG streams must stay in lockstep after the call, too.
            assert_eq!(r1.below(1 << 30), r2.below(1 << 30), "rng drift {spec:?} d={d}");

            let comp_v = spec.build_vec(d);
            let x = random_vec(d, &mut rng);
            let mut r1 = rng.derive(2);
            let mut r2 = rng.derive(2);
            let (v, vcost) = comp_v.compress_vec(&x, &mut r1);
            let mut v2 = vec![f64::NAN; 1];
            let vcost2 = comp_v.compress_vec_into(&x, &mut v2, &mut scratch, &mut r2);
            assert_eq!(v, v2, "compress_vec {spec:?} d={d}");
            assert_eq!(vcost, vcost2, "vec cost {spec:?} d={d}");
            assert_eq!(r1.below(1 << 30), r2.below(1 << 30), "vec rng drift {spec:?} d={d}");
        }
    }
}

// ── oracle `*_into` kernels ──────────────────────────────────────────────

#[test]
fn oracle_into_kernels_match_allocating_bitwise() {
    let mut rng = Rng::new(49);
    for (m, d) in [(1usize, 1usize), (5, 3), (40, 12)] {
        let a = random_mat(m, d, &mut rng);
        let b: Vector = (0..m).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect();
        let p = LogisticProblem::new(a, b);
        let x = random_vec(d, &mut rng);
        let mut scratch = OracleScratch::default();

        let g = p.grad(&x);
        let mut g2 = vec![f64::NAN; 1];
        p.grad_into(&x, &mut g2, &mut scratch);
        assert_eq!(g, g2, "grad m={m} d={d}");

        let h = p.hess(&x);
        let mut h2 = Mat::from_fn(1, 2, |_, _| f64::NAN);
        p.hess_into(&x, &mut h2, &mut scratch);
        assert_eq!(h, h2, "hess m={m} d={d}");
    }
}

// ── RNG sampling `_into` ─────────────────────────────────────────────────

#[test]
fn sample_without_replacement_into_matches_allocating() {
    for (n, k) in [(1usize, 0usize), (1, 1), (10, 3), (10, 10), (64, 17)] {
        let mut r1 = Rng::new(50);
        let mut r2 = Rng::new(50);
        let idx = r1.sample_without_replacement(n, k);
        let mut idx2 = vec![usize::MAX; 2];
        r2.sample_without_replacement_into(n, k, &mut idx2);
        assert_eq!(idx, idx2, "n={n} k={k}");
        assert_eq!(r1.below(1 << 30), r2.below(1 << 30), "rng drift n={n} k={k}");
    }
}
