//! Integration tests for the full three-layer path: JAX/Pallas artifacts
//! (built by `make artifacts`) loaded and executed through PJRT, compared
//! against the native Rust oracle, and driven end-to-end by the coordinator.
//!
//! These tests require `artifacts/` to exist; `make test` orders that. When
//! artifacts are missing they **fail** with a pointer to `make artifacts`
//! (skipping silently would hide a broken build pipeline).
//!
//! The whole target is gated behind the `pjrt` cargo feature
//! (`required-features` in Cargo.toml); the default test suite stays
//! dependency-light and artifact-free.

#![cfg(feature = "pjrt")]

use basis_learn::config::{Algorithm, RunConfig};
use basis_learn::coordinator::{run_federated_with, run_federated};
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::linalg::Mat;
use basis_learn::problem::{LocalProblem, LogisticProblem};
use basis_learn::runtime::{PjrtProblem, Runtime};
use std::path::Path;
use std::rc::Rc;

fn artifacts_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").leak()
}

fn load_runtime() -> Rc<Runtime> {
    Rc::new(
        Runtime::load(artifacts_dir())
            .expect("artifacts missing — run `make artifacts` before `cargo test`"),
    )
}

fn test_fed() -> FederatedDataset {
    // (m, d) = (30, 10) is in aot.py's DEFAULT_SHAPES.
    FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 3,
        m_per_client: 30,
        dim: 10,
        intrinsic_dim: 4,
        noise: 0.0,
        seed: 99,
    })
}

#[test]
fn pjrt_matches_native_oracle() {
    let rt = load_runtime();
    let fed = test_fed();
    let c = &fed.clients[0];
    let native = LogisticProblem::new(c.a.clone(), c.b.clone());
    let pjrt = PjrtProblem::new(rt, c.a.clone(), c.b.clone()).unwrap();

    let mut x = vec![0.0; 10];
    for (i, xi) in x.iter_mut().enumerate() {
        *xi = 0.1 * (i as f64) - 0.4;
    }

    // Loss.
    let (l_native, g_native) = native.loss_grad(&x);
    let (l_pjrt, g_pjrt) = pjrt.loss_grad(&x);
    assert!(
        (l_native - l_pjrt).abs() < 1e-12,
        "loss mismatch: native {l_native} vs pjrt {l_pjrt}"
    );
    for (a, b) in g_native.iter().zip(&g_pjrt) {
        assert!((a - b).abs() < 1e-12, "grad mismatch: {a} vs {b}");
    }

    // Hessian.
    let h_native = native.hess(&x);
    let h_pjrt = pjrt.hess(&x);
    let err = (&h_native - &h_pjrt).fro_norm();
    assert!(err < 1e-12, "hessian mismatch ‖Δ‖={err}");
    assert!(h_pjrt.is_symmetric(0.0));
}

#[test]
fn pjrt_rejects_unknown_shape() {
    let rt = load_runtime();
    let a = Mat::zeros(13, 7); // not in the shape grid
    let b = vec![1.0; 13];
    let err = PjrtProblem::new(rt, a, b).err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("aot.py"), "{msg}");
}

#[test]
fn bl1_end_to_end_over_pjrt() {
    // The full production stack: BL1 coordinator (L3) with every local
    // loss/grad/Hessian served by the AOT JAX+Pallas artifacts (L2+L1).
    let rt = load_runtime();
    let fed = test_fed();
    let locals: Vec<Box<dyn LocalProblem>> = fed
        .clients
        .iter()
        .map(|c| {
            Box::new(PjrtProblem::new(rt.clone(), c.a.clone(), c.b.clone()).unwrap())
                as Box<dyn LocalProblem>
        })
        .collect();
    let features: Vec<Option<Mat>> = fed.clients.iter().map(|c| Some(c.a.clone())).collect();
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        rounds: 200,
        lambda: 1e-3,
        target_gap: 1e-10,
        ..RunConfig::default()
    };
    let out = run_federated_with(&locals, features, &cfg).unwrap();
    assert!(out.final_gap() <= 1e-10, "gap={}", out.final_gap());

    // And the PJRT trajectory must match the native one bit-for-bit in
    // round count and near-exactly in iterates (same seeds, same math).
    let native = run_federated(&fed, &cfg).unwrap();
    assert_eq!(out.history.records.len(), native.history.records.len());
    for (a, b) in out.x_final.iter().zip(&native.x_final) {
        assert!((a - b).abs() < 1e-9, "pjrt {a} vs native {b}");
    }
}

