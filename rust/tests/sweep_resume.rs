//! End-to-end crash/resume drill for the sweep engine: run a grid, tear the
//! `runs.jsonl` sink mid-line the way a SIGKILL would, recover with the
//! resume planner, execute only what's missing, and check the re-aggregated
//! summary is byte-identical to the uninterrupted run's — at a different
//! `--jobs` level, which the determinism contract says must not matter.

use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, RunConfig};
use basis_learn::data::SyntheticSpec;
use basis_learn::sweep::{
    aggregate, load_jsonl, plan_resume, ranked, rows_from_results, run_cells, run_row,
    summary_jsonl, DatasetRef, JsonlSink, RunRow, SweepCell, SweepSpec, SWEEP_TARGETS,
};
use std::path::PathBuf;

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        algos: vec![Algorithm::Bl1, Algorithm::FedNl],
        datasets: vec![DatasetRef::Synthetic(SyntheticSpec {
            n_clients: 3,
            m_per_client: 20,
            dim: 8,
            intrinsic_dim: 3,
            noise: 0.0,
            seed: 0,
        })],
        hess_comps: vec![CompressorSpec::TopK(3)],
        seeds: vec![1, 2, 3],
        base: RunConfig { rounds: 40, target_gap: 1e-10, ..RunConfig::default() },
        ..SweepSpec::default()
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bl_sweep_resume_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn summary_bytes(rows: &[RunRow]) -> String {
    let summaries = aggregate(rows, &SWEEP_TARGETS);
    summary_jsonl(&summaries, &ranked(&summaries))
}

/// Cut `runs.jsonl` after `keep` whole rows plus a torn fragment of the
/// next one — the on-disk shape an interrupted sweep leaves behind.
fn tear_after(path: &PathBuf, keep: usize) {
    let bytes = std::fs::read(path).unwrap();
    let mut newlines = 0usize;
    let mut cut_start = bytes.len();
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            newlines += 1;
            if newlines == keep {
                cut_start = i + 1;
                break;
            }
        }
    }
    assert!(cut_start < bytes.len(), "file has fewer than {keep} full rows to tear after");
    // Leave half of the next row behind.
    let next_end = bytes[cut_start..]
        .iter()
        .position(|&b| b == b'\n')
        .map(|p| cut_start + p)
        .unwrap_or(bytes.len());
    let cut = cut_start + (next_end - cut_start) / 2;
    std::fs::write(path, &bytes[..cut]).unwrap();
}

#[test]
fn resume_after_torn_tail_matches_uninterrupted_run() {
    let dir = tmp_dir("torn");
    let cells = tiny_spec().expand();
    assert_eq!(cells.len(), 6);

    // Uninterrupted reference at --jobs 2.
    let full = run_cells(&cells, 2, |_| {});
    let full_summary = summary_bytes(&rows_from_results(&full, &SWEEP_TARGETS));

    // Simulate the interrupted sweep: 3 complete rows + half of the 4th.
    // (Write in declaration order — any completion order gives the same
    // resume behaviour since matching is by key, not position.)
    let path = dir.join("runs.jsonl");
    let mut sink = JsonlSink::create(&path).unwrap();
    for r in &full {
        sink.push(&run_row(r, &SWEEP_TARGETS)).unwrap();
    }
    drop(sink);
    tear_after(&path, 3);

    // Recover and plan: the torn row is dropped, 3 survive, 3 re-run.
    let load = load_jsonl(&path).unwrap();
    assert!(load.torn_tail);
    assert_eq!(load.rows.len(), 3);
    let prior: Vec<RunRow> = load.rows.iter().map(|j| RunRow::from_json(j).unwrap()).collect();
    let plan = plan_resume(&cells, &prior, &SWEEP_TARGETS);
    assert_eq!(plan.done.len(), 3);
    assert_eq!(plan.todo.len(), 3);
    let done_keys: Vec<String> = plan.done.iter().map(|r| r.key()).collect();
    for c in &plan.todo {
        assert!(!done_keys.contains(&c.key()), "cell scheduled twice: {}", c.key());
    }

    // Execute exactly N − k cells, at a different jobs level, and merge.
    let rest = run_cells(&plan.todo, 1, |_| {});
    assert_eq!(rest.len(), 3);
    let mut rows = plan.done.clone();
    rows.extend(rows_from_results(&rest, &SWEEP_TARGETS));
    rows.sort_by_key(|r| r.id);
    assert_eq!(summary_bytes(&rows), full_summary);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_reruns_failed_cells_and_preserves_completed_ones() {
    let dir = tmp_dir("failed");
    let mut cells = tiny_spec().expand();
    // Sabotage one cell so its first run fails (RankR gradient compressor
    // panics in build_vec — the worst-case in-cell failure).
    cells[1].cfg.algorithm = Algorithm::Diana;
    cells[1].cfg.grad_comp = CompressorSpec::RankR(1);

    let first = run_cells(&cells, 2, |_| {});
    assert!(!first[1].status.is_ok());
    let path = dir.join("runs.jsonl");
    let mut sink = JsonlSink::create(&path).unwrap();
    for r in &first {
        sink.push(&run_row(r, &SWEEP_TARGETS)).unwrap();
    }
    drop(sink);

    // Resume over an intact file: only the failed cell is scheduled.
    let load = load_jsonl(&path).unwrap();
    assert!(!load.torn_tail);
    let prior: Vec<RunRow> = load.rows.iter().map(|j| RunRow::from_json(j).unwrap()).collect();
    let plan = plan_resume(&cells, &prior, &SWEEP_TARGETS);
    assert_eq!(plan.done.len(), cells.len() - 1);
    assert_eq!(plan.todo.len(), 1);
    assert_eq!(plan.todo[0].id, 1);

    // Fix the cell and re-run it; the merged summary matches a from-scratch
    // run of the fixed grid.
    let fixed: Vec<SweepCell> = tiny_spec().expand();
    let rerun = run_cells(&[fixed[1].clone()], 1, |_| {});
    assert!(rerun[0].status.is_ok());
    let mut rows = plan.done.clone();
    rows.extend(rows_from_results(&rerun, &SWEEP_TARGETS));
    rows.sort_by_key(|r| r.id);
    let reference = run_cells(&fixed, 3, |_| {});
    assert_eq!(
        summary_bytes(&rows),
        summary_bytes(&rows_from_results(&reference, &SWEEP_TARGETS))
    );

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_with_complete_file_schedules_nothing() {
    let cells = tiny_spec().expand();
    let results = run_cells(&cells, 2, |_| {});
    let prior = rows_from_results(&results, &SWEEP_TARGETS);
    let plan = plan_resume(&cells, &prior, &SWEEP_TARGETS);
    assert!(plan.todo.is_empty());
    assert_eq!(plan.done.len(), cells.len());
    // Aggregating the recovered rows alone reproduces the full summary.
    assert_eq!(summary_bytes(&plan.done), summary_bytes(&prior));
}

#[test]
fn torn_single_row_file_reruns_everything() {
    let dir = tmp_dir("all_torn");
    let path = dir.join("runs.jsonl");
    std::fs::write(&path, "{\"cell\":0,\"group\":\"g\",\"seed\":1,\"status\":\"o").unwrap();
    let load = load_jsonl(&path).unwrap();
    assert!(load.torn_tail);
    assert!(load.rows.is_empty());
    let cells = tiny_spec().expand();
    let prior: Vec<RunRow> = load.rows.iter().filter_map(|j| RunRow::from_json(j).ok()).collect();
    let plan = plan_resume(&cells, &prior, &SWEEP_TARGETS);
    assert_eq!(plan.todo.len(), cells.len());
    std::fs::remove_dir_all(&dir).unwrap();
}
