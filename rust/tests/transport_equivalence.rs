//! The transport layer's determinism contract: for **every** `Algorithm`
//! variant, a federated run must produce a byte-identical `History`
//! (rounds, bits up/down, gaps, distances) under the `Lockstep`, `Threaded`,
//! `Tcp` and multi-process `Listen` backends, at any worker count — client
//! randomness comes from per-client streams and absorb order is pinned, so
//! scheduling cannot leak into results. Under `Tcp` every packet
//! additionally crosses the byte-level wire codec over real loopback
//! sockets, so the identical `CommTally` columns prove the decoded frames
//! reconcile with the in-process bit accounting to the last bit. Under
//! `Listen` the workers are real `repro worker` child processes that
//! rebuild their shards from the handshake's data recipe.
//!
//! Configurations deliberately exercise the stochastic paths (Rand-K /
//! dithering client compressors, partial participation, lazy-gradient ξ
//! schedules, bidirectional compression) — the cases where a scheduling
//! leak would actually show up.

use basis_learn::compressors::CompressorSpec;
use basis_learn::config::{Algorithm, RunConfig, TransportSpec};
use basis_learn::coordinator::{run_federated, run_federated_listen, RunOutput};
use basis_learn::data::{FederatedDataset, SyntheticSpec};
use basis_learn::obs::NOOP;
use std::process::{Command, Stdio};

fn fed(seed: u64) -> FederatedDataset {
    FederatedDataset::synthetic(&SyntheticSpec {
        n_clients: 5,
        m_per_client: 25,
        dim: 10,
        intrinsic_dim: 4,
        noise: 0.0,
        seed,
    })
}

/// A config per algorithm that exercises its interesting wire paths
/// (stochastic compression, PP, ξ < 1, bidirectional) in few rounds.
fn cfg_for(algo: Algorithm) -> RunConfig {
    use Algorithm::*;
    let base = RunConfig {
        algorithm: algo,
        lambda: 1e-3,
        target_gap: 0.0, // run every round — compare full traces
        seed: 99,
        ..RunConfig::default()
    };
    match algo {
        Newton => RunConfig { rounds: 8, ..base },
        Bl1 => RunConfig {
            rounds: 20,
            hess_comp: CompressorSpec::TopK(4),
            model_comp: CompressorSpec::TopK(5),
            p: 0.5,
            ..base
        },
        Bl2 => RunConfig {
            rounds: 20,
            hess_comp: CompressorSpec::RandK(4),
            tau: Some(3),
            p: 0.5,
            ..base
        },
        Bl3 => RunConfig {
            rounds: 20,
            hess_comp: CompressorSpec::TopK(10),
            model_comp: CompressorSpec::TopK(5),
            tau: Some(3),
            p: 0.5,
            ..base
        },
        FedNl => RunConfig { rounds: 15, hess_comp: CompressorSpec::RankR(1), ..base },
        FedNlPp => RunConfig {
            rounds: 20,
            hess_comp: CompressorSpec::RankR(1),
            tau: Some(3),
            ..base
        },
        FedNlBc => RunConfig {
            rounds: 20,
            hess_comp: CompressorSpec::TopK(50),
            model_comp: CompressorSpec::TopK(5),
            ..base
        },
        Nl1 => RunConfig { rounds: 15, hess_comp: CompressorSpec::RandK(2), ..base },
        Dingo => RunConfig { rounds: 4, ..base },
        Gd => RunConfig { rounds: 30, ..base },
        Diana => RunConfig {
            rounds: 50,
            grad_comp: CompressorSpec::Dithering(Some(4)),
            ..base
        },
        Adiana => RunConfig {
            rounds: 50,
            grad_comp: CompressorSpec::Dithering(None),
            ..base
        },
        SLocalGd => RunConfig { rounds: 60, ..base },
        Artemis => RunConfig {
            rounds: 50,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::TopK(4),
            tau: Some(3),
            ..base
        },
        Dore => RunConfig {
            rounds: 50,
            grad_comp: CompressorSpec::Dithering(None),
            model_comp: CompressorSpec::Dithering(None),
            ..base
        },
    }
}

fn assert_identical(algo: Algorithm, a: &RunOutput, b: &RunOutput, what: &str) {
    assert_eq!(
        a.history.records.len(),
        b.history.records.len(),
        "{algo}: round counts differ under {what}"
    );
    // Byte-identical trace: every f64 must match exactly, not approximately.
    assert_eq!(a.history.records, b.history.records, "{algo}: history differs under {what}");
    assert_eq!(
        a.history.setup_bits_per_node, b.history.setup_bits_per_node,
        "{algo}: setup bits differ under {what}"
    );
    assert_eq!(a.history.label, b.history.label, "{algo}: label differs under {what}");
    assert_eq!(a.x_final, b.x_final, "{algo}: final iterate differs under {what}");
}

#[test]
fn every_algorithm_is_backend_invariant() {
    for &algo in Algorithm::all() {
        let f = fed(2024);
        let cfg = cfg_for(algo);
        let lockstep = run_federated(&f, &cfg).unwrap_or_else(|e| panic!("{algo} lockstep: {e:#}"));
        assert!(
            lockstep.final_gap().is_finite(),
            "{algo}: lockstep run did not produce a finite gap"
        );
        for workers in [1usize, 3] {
            let cfg_t =
                RunConfig { transport: TransportSpec::Threaded(workers), ..cfg.clone() };
            let threaded = run_federated(&f, &cfg_t)
                .unwrap_or_else(|e| panic!("{algo} threaded:{workers}: {e:#}"));
            assert_identical(algo, &lockstep, &threaded, &format!("threaded:{workers}"));
        }
        for workers in [1usize, 3] {
            let cfg_t = RunConfig { transport: TransportSpec::Tcp(workers), ..cfg.clone() };
            let tcp = run_federated(&f, &cfg_t)
                .unwrap_or_else(|e| panic!("{algo} tcp:{workers}: {e:#}"));
            assert_identical(algo, &lockstep, &tcp, &format!("tcp:{workers}"));
        }
    }
}

#[test]
fn every_algorithm_is_process_invariant() {
    // The fourth backend: a real multi-process federation. The round loop
    // listens on an ephemeral loopback port and two *separate operating
    // system processes* of the compiled `repro` binary join it, rebuild
    // their shards from the Assign handshake's data recipe, and serve the
    // rounds. Every packet crosses process boundaries through the byte
    // codec; the trace must still be bit-identical to lockstep.
    for &algo in Algorithm::all() {
        let f = fed(2024);
        let cfg = cfg_for(algo);
        let lockstep = run_federated(&f, &cfg).unwrap_or_else(|e| panic!("{algo} lockstep: {e:#}"));
        let cfg_l = RunConfig {
            transport: TransportSpec::Listen { addr: "127.0.0.1:0".into(), workers: 2 },
            ..cfg
        };
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let out = std::thread::scope(|s| {
            let server = s.spawn(|| {
                run_federated_listen(&f, &cfg_l, &NOOP, &mut |a| addr_tx.send(a).unwrap())
            });
            let addr = addr_rx.recv().expect("listen address").to_string();
            let children: Vec<_> = (0..2)
                .map(|i| {
                    Command::new(env!("CARGO_BIN_EXE_repro"))
                        .args(["worker", "--connect", &addr])
                        .stdout(Stdio::null())
                        .stderr(Stdio::inherit())
                        .spawn()
                        .unwrap_or_else(|e| panic!("{algo}: spawning worker process {i}: {e}"))
                })
                .collect();
            let out = server.join().expect("server thread panicked");
            for (i, mut child) in children.into_iter().enumerate() {
                let status = child.wait().expect("waiting on a worker process");
                assert!(status.success(), "{algo}: worker process {i} exited with {status}");
            }
            out
        })
        .unwrap_or_else(|e| panic!("{algo} listen: {e:#}"));
        assert_identical(algo, &lockstep, &out, "two repro worker processes");
    }
}

#[test]
fn worker_count_may_exceed_clients() {
    // More workers than clients must clamp, not hang or skew routing.
    let f = fed(7);
    let cfg = RunConfig {
        algorithm: Algorithm::Bl1,
        rounds: 10,
        target_gap: 0.0,
        ..RunConfig::default()
    };
    let a = run_federated(&f, &cfg).unwrap();
    let cfg_t = RunConfig { transport: TransportSpec::Threaded(64), ..cfg.clone() };
    let b = run_federated(&f, &cfg_t).unwrap();
    assert_identical(Algorithm::Bl1, &a, &b, "threaded:64");
    let cfg_tcp = RunConfig { transport: TransportSpec::Tcp(64), ..cfg };
    let c = run_federated(&f, &cfg_tcp).unwrap();
    assert_identical(Algorithm::Bl1, &a, &c, "tcp:64");
}

#[test]
fn auto_worker_count_matches_lockstep() {
    // `threaded` (k = 0) resolves to the hardware parallelism — still
    // bit-identical.
    let f = fed(8);
    let cfg = RunConfig {
        algorithm: Algorithm::Bl2,
        rounds: 12,
        tau: Some(2),
        target_gap: 0.0,
        ..RunConfig::default()
    };
    let a = run_federated(&f, &cfg).unwrap();
    let cfg_t = RunConfig { transport: TransportSpec::Threaded(0), ..cfg };
    let b = run_federated(&f, &cfg_t).unwrap();
    assert_identical(Algorithm::Bl2, &a, &b, "threaded (auto)");
}

#[test]
fn broken_config_does_not_hang_under_threaded() {
    // A configuration that fails at construction (RankR has no vector form,
    // so build_vec panics in the method split, before the pool spawns) must
    // not leave the run hanging or silently succeeding under the threaded
    // backend. The *in-round* failure path — a client panicking on a worker
    // mid-exchange — is covered by the worker-pool unit tests in
    // `transport::threaded`.
    let f = fed(9);
    let cfg = RunConfig {
        algorithm: Algorithm::Diana,
        grad_comp: CompressorSpec::RankR(1), // RankR::build_vec panics
        rounds: 5,
        transport: TransportSpec::Threaded(2),
        ..RunConfig::default()
    };
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_federated(&f, &cfg)));
    // Either a clean Err or a propagated panic is acceptable — what is not
    // acceptable is hanging (the test harness would time out) or silently
    // succeeding.
    match res {
        Ok(out) => assert!(out.is_err(), "bad compressor must not run"),
        Err(_) => {}
    }
}
