//! Property suite for the byte-level wire codec (`transport::codec`), the
//! frame session layer (`transport::session`), and their interaction with
//! the [`PacketPool`] recycler.
//!
//! The codec's contract is *exactness*: every `f64` travels as its IEEE-754
//! bit pattern, so a decoded packet is bit-identical to the encoded one —
//! including NaN payloads, signed zeros and subnormals — and every length
//! field is validated before allocation, so truncated or hostile bytes are
//! `anyhow` errors, never panics. This suite drives those properties with
//! seeded-random packets over the full kind registry, then checks the
//! framing layer end-to-end over an in-memory stream.

use basis_learn::compressors::BitCost;
use basis_learn::linalg::Mat;
use basis_learn::rng::Rng;
use basis_learn::transport::codec::{
    decode_header, decode_packet, encode_header, encode_packet, encode_packet_into, wire_id,
    FrameHeader, FrameKind, HEADER_LEN, MAGIC, MAX_BODY_LEN, VERSION, WIRE_KINDS,
};
use basis_learn::transport::kinds::KINDS;
use basis_learn::transport::session::{FramePayload, Session};
use basis_learn::transport::{Packet, PacketPool, Payload};
use std::io::{Cursor, Read, Write};

// ── helpers ────────────────────────────────────────────────────────────

/// Bit-exact packet equality: kinds, costs and payloads compared through
/// `to_bits`, so NaN == NaN and -0.0 != 0.0.
fn assert_bit_identical(a: &Packet, b: &Packet, what: &str) {
    assert_eq!(a.msgs.len(), b.msgs.len(), "{what}: message count");
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    for (i, (x, y)) in a.msgs.iter().zip(&b.msgs).enumerate() {
        assert_eq!(x.kind, y.kind, "{what}: msg {i} kind");
        assert_eq!(x.cost.floats.to_bits(), y.cost.floats.to_bits(), "{what}: msg {i} cost");
        assert_eq!(
            x.cost.aux_bits.to_bits(),
            y.cost.aux_bits.to_bits(),
            "{what}: msg {i} aux cost"
        );
        match (&x.payload, &y.payload) {
            (Payload::Vector(p), Payload::Vector(q)) => assert_eq!(bits(p), bits(q), "{what}"),
            (Payload::Scalars(p), Payload::Scalars(q)) => assert_eq!(bits(p), bits(q), "{what}"),
            (Payload::Flags(p), Payload::Flags(q)) => assert_eq!(p, q, "{what}"),
            (Payload::Matrix(p), Payload::Matrix(q)) => {
                assert_eq!((p.rows(), p.cols()), (q.rows(), q.cols()), "{what}: msg {i} shape");
                assert_eq!(bits(p.data()), bits(q.data()), "{what}: msg {i} matrix");
            }
            _ => panic!("{what}: msg {i} changed payload variant"),
        }
    }
}

/// A value stream that sprinkles the adversarial f64s through ordinary
/// normals: NaN with a payload, ±0.0, subnormals, infinities.
fn gnarly_f64(rng: &mut Rng) -> f64 {
    match rng.below(12) {
        0 => f64::NAN,
        1 => f64::from_bits(0x7ff8_0000_0000_0000 | rng.next_u64() & 0xf_ffff_ffff_ffff),
        2 => -0.0,
        3 => 0.0,
        4 => f64::from_bits(rng.below(4096) as u64 + 1), // subnormal
        5 => -f64::from_bits(rng.below(4096) as u64 + 1),
        6 => f64::INFINITY,
        7 => f64::NEG_INFINITY,
        _ => rng.normal() * 10f64.powi(rng.below(7) as i32 - 3),
    }
}

fn random_cost(rng: &mut Rng) -> BitCost {
    BitCost { floats: rng.below(1000) as f64, aux_bits: rng.below(100_000) as f64 }
}

/// A random packet drawing kinds from the full registry and payloads from
/// all four variants, sized to exercise empty and non-trivial shapes.
fn random_packet(rng: &mut Rng) -> Packet {
    let mut p = Packet::empty();
    for _ in 0..rng.below(6) {
        let kind = KINDS[rng.below(KINDS.len())].name;
        let cost = random_cost(rng);
        match rng.below(4) {
            0 => {
                let n = rng.below(40);
                p.push_vector(kind, (0..n).map(|_| gnarly_f64(rng)).collect(), cost);
            }
            1 => {
                let (r, c) = (rng.below(7), rng.below(7));
                p.push_matrix(kind, Mat::from_fn(r, c, |_, _| 0.0), cost);
                if let Some(Payload::Matrix(m)) = p.msgs.last_mut().map(|m| &mut m.payload) {
                    for x in m.data_mut() {
                        *x = gnarly_f64(rng);
                    }
                }
            }
            2 => {
                let n = rng.below(10);
                p.push_scalars(kind, (0..n).map(|_| gnarly_f64(rng)).collect(), cost);
            }
            _ => {
                let n = rng.below(16);
                p.push_flags(kind, (0..n).map(|_| rng.bernoulli(0.5)).collect(), cost);
            }
        }
    }
    p
}

/// In-memory bidirectional-looking stream: reads consume from the front,
/// writes append at the end (a loopback socket with ourselves on both ends).
struct Loopback(Cursor<Vec<u8>>);

impl Read for Loopback {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.0.read(buf)
    }
}

impl Write for Loopback {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let pos = self.0.position();
        self.0.set_position(self.0.get_ref().len() as u64);
        let n = self.0.write(buf)?;
        self.0.set_position(pos);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

// ── codec properties ───────────────────────────────────────────────────

#[test]
fn seeded_random_packets_round_trip_bit_for_bit() {
    let mut rng = Rng::new(0xC0DEC);
    for trial in 0..200 {
        let p = random_packet(&mut rng);
        let body = encode_packet(&p).expect("encode");
        let q = decode_packet(&body).expect("decode");
        assert_bit_identical(&p, &q, &format!("trial {trial}"));
    }
}

#[test]
fn every_registered_kind_crosses_the_codec() {
    // Both directions of the exhaustiveness contract: every registry entry
    // has a wire id (encodable + decodable), and every wire id names a
    // registered kind. This is the compile-time mirror of the audit's
    // codec-sync rule.
    assert_eq!(WIRE_KINDS.len(), KINDS.len());
    for k in KINDS {
        let id = wire_id(k.name).expect("registered kind must have a wire id");
        assert_eq!(WIRE_KINDS[id as usize], k.name, "wire ids are positional");
        let mut p = Packet::empty();
        p.push_vector(k.name, vec![1.5, -2.5], BitCost::floats(2));
        let q = decode_packet(&encode_packet(&p).expect("encode")).expect("decode");
        assert_eq!(q.msgs[0].kind, k.name);
    }
    for w in WIRE_KINDS {
        assert!(
            KINDS.iter().any(|k| k.name == *w),
            "wire kind {w:?} is not in the registry"
        );
    }
}

#[test]
fn random_truncation_never_panics_and_always_errors() {
    let mut rng = Rng::new(0x7256);
    for _ in 0..50 {
        let mut p = random_packet(&mut rng);
        // Guarantee at least one message so every strict prefix is short.
        p.push_vector("model", vec![1.0], BitCost::floats(1));
        let body = encode_packet(&p).expect("encode");
        for cut in 0..body.len() {
            assert!(decode_packet(&body[..cut]).is_err(), "prefix {cut} decoded");
        }
    }
}

#[test]
fn random_byte_soup_never_panics() {
    let mut rng = Rng::new(0x50FA);
    for _ in 0..300 {
        let n = rng.below(200);
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        // Any outcome but a panic is acceptable; decode must stay total.
        let _ = decode_packet(&bytes);
        if bytes.len() >= HEADER_LEN {
            let mut hdr = [0u8; HEADER_LEN];
            hdr.copy_from_slice(&bytes[..HEADER_LEN]);
            let _ = decode_header(&hdr);
        }
    }
}

#[test]
fn encode_into_appends_without_disturbing_the_prefix() {
    let mut p = Packet::empty();
    p.push_scalars("avg", vec![3.25], BitCost::floats(1));
    let mut buf = vec![0xAB, 0xCD];
    encode_packet_into(&p, &mut buf).expect("encode");
    assert_eq!(&buf[..2], &[0xAB, 0xCD]);
    let q = decode_packet(&buf[2..]).expect("decode");
    assert_bit_identical(&p, &q, "appended body");
}

// ── session framing ────────────────────────────────────────────────────

#[test]
fn session_frames_random_packets_in_order() {
    let mut rng = Rng::new(0x5E55);
    let packets: Vec<Packet> = (0..20).map(|_| random_packet(&mut rng)).collect();
    let mut sess = Session::new(Loopback(Cursor::new(Vec::new())));
    for (i, p) in packets.iter().enumerate() {
        sess.send_packet(&FrameHeader::packet(i, i % 3, i * 7), p).expect("send");
    }
    sess.send_control(FrameKind::Bye, 4).expect("send bye");
    for (i, p) in packets.iter().enumerate() {
        let (hdr, payload) = sess.recv().expect("recv");
        assert_eq!(hdr, FrameHeader::packet(i, i % 3, i * 7), "frame {i} header");
        match payload {
            FramePayload::Packet(q) => assert_bit_identical(p, &q, &format!("frame {i}")),
            other => panic!("frame {i}: expected a packet, got {other:?}"),
        }
    }
    let (hdr, payload) = sess.recv().expect("recv bye");
    assert_eq!(hdr, FrameHeader::control(FrameKind::Bye, 4));
    assert!(matches!(payload, FramePayload::Control(FrameKind::Bye)));
}

#[test]
fn session_error_frames_carry_their_message() {
    let mut sess = Session::new(Loopback(Cursor::new(Vec::new())));
    let at = FrameHeader::packet(3, 1, 9);
    sess.send_error(&at, "local Hessian exploded").expect("send");
    let (hdr, payload) = sess.recv().expect("recv");
    assert_eq!((hdr.round, hdr.exchange, hdr.client), (3, 1, 9));
    assert_eq!(hdr.kind, FrameKind::Error);
    match payload {
        FramePayload::Error(msg) => assert_eq!(msg, "local Hessian exploded"),
        other => panic!("expected an error frame, got {other:?}"),
    }
}

#[test]
fn hostile_body_length_is_rejected_before_allocation() {
    // The header's `body_len` field is peer-controlled on a real connection.
    // Hand-craft an otherwise-valid header claiming an absurd body: `recv`
    // must fail on the MAX_BODY_LEN cap *before* sizing its scratch buffer
    // to the claimed length (no body bytes follow, so a decoder that
    // allocated first would block on a 4 GiB read instead of erroring).
    for claimed in [MAX_BODY_LEN as u32 + 1, u32::MAX] {
        let mut raw = Vec::new();
        raw.extend_from_slice(&MAGIC);
        raw.push(VERSION);
        raw.push(FrameKind::Packet as u8);
        raw.extend_from_slice(&0u64.to_le_bytes()); // round
        raw.extend_from_slice(&0u64.to_le_bytes()); // exchange
        raw.extend_from_slice(&0u64.to_le_bytes()); // client
        raw.extend_from_slice(&claimed.to_le_bytes());
        assert_eq!(raw.len(), HEADER_LEN, "hand-built header drifted from the layout");
        let mut sess = Session::new(Loopback(Cursor::new(raw)));
        let err = sess.recv().expect_err("hostile body length accepted");
        let msg = format!("{err:#}");
        assert!(msg.contains("MAX_BODY_LEN") && msg.contains("hostile"), "{msg}");
    }
    // The cap binds symmetrically: the encoder refuses to produce a header
    // the receiving side would reject.
    let mut out = Vec::new();
    let hdr = FrameHeader::control(FrameKind::Packet, 0);
    assert!(encode_header(&hdr, MAX_BODY_LEN + 1, &mut out).is_err());
}

#[test]
fn header_encode_is_exactly_header_len_bytes() {
    let mut buf = Vec::new();
    encode_header(&FrameHeader::control(FrameKind::Hello, 2), 0, &mut buf).expect("encode");
    assert_eq!(buf.len(), HEADER_LEN);
}

// ── pool interaction ───────────────────────────────────────────────────

#[test]
fn pooled_packets_encode_without_stale_bytes() {
    // Build a large packet from pooled buffers, encode it, recycle it, then
    // build a *smaller* packet from the same pool. The recycled buffers have
    // stale capacity beyond the new lengths; the encoding must match a
    // fresh, never-pooled packet byte for byte.
    let pool = PacketPool::new();

    let mut big = pool.packet();
    let mut v = pool.vec_f64(64);
    v.extend((0..64).map(|i| i as f64 + 0.5));
    big.push_vector("model", v, BitCost::floats(64));
    big.push_matrix("hess_delta", pool.zeros_mat(8, 8), BitCost::floats(64));
    let mut f = pool.vec_bool(32);
    f.extend((0..32).map(|i| i % 3 == 0));
    big.push_flags("xi", f, BitCost::bits(32.0));
    let big_bytes = encode_packet(&big).expect("encode big");
    pool.recycle_packet(big);

    let mut small = pool.packet();
    let mut v = pool.vec_f64(3);
    v.extend([1.0, 2.0, 3.0]);
    small.push_vector("model", v, BitCost::floats(3));
    let mut f = pool.vec_bool(2);
    f.extend([true, false]);
    small.push_flags("xi", f, BitCost::bits(2.0));
    let pooled_bytes = encode_packet(&small).expect("encode pooled");

    let mut fresh = Packet::empty();
    fresh.push_vector("model", vec![1.0, 2.0, 3.0], BitCost::floats(3));
    fresh.push_flags("xi", vec![true, false], BitCost::bits(2.0));
    let fresh_bytes = encode_packet(&fresh).expect("encode fresh");

    assert_ne!(big_bytes, pooled_bytes, "recycling must not preserve old contents");
    assert_eq!(pooled_bytes, fresh_bytes, "pooled buffers leaked stale bytes");
    let q = decode_packet(&pooled_bytes).expect("decode pooled");
    assert_bit_identical(&fresh, &q, "pooled round-trip");
}

#[test]
fn decode_then_recycle_then_reencode_is_stable() {
    // The TCP receive path decodes into fresh buffers which algorithms may
    // hand to a pool; a second encode of a re-acquired packet must be
    // byte-identical to the first.
    let pool = PacketPool::new();
    let mut rng = Rng::new(0xB00C);
    for _ in 0..20 {
        let p = random_packet(&mut rng);
        let bytes = encode_packet(&p).expect("encode");
        let decoded = decode_packet(&bytes).expect("decode");
        let copy = pool.clone_packet(&decoded);
        let copy_bytes = encode_packet(&copy).expect("re-encode");
        assert_eq!(bytes, copy_bytes, "pooled clone changed the encoding");
        pool.recycle_packet(decoded);
        pool.recycle_packet(copy);
    }
}
